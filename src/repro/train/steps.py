"""jit-able step builders: train_step / prefill_step / decode_step.

These close over the ArchConfig and optimizer config so the jitted
signature is pure pytrees — exactly what the dry-run lowers with
ShapeDtypeStructs and what the training loop runs with real arrays.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import build
from repro.optim import (
    AdamWConfig,
    accumulated_value_and_grad,
    adamw_init,
    adamw_update,
    compress_tree,
    init_error_state,
)

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step", "make_train_state", "opt_axes"]


def make_train_state(cfg, optim_cfg: AdamWConfig, rng, compress: bool = False):
    api = build(cfg)
    params, axes = api.init(rng)
    opt_state = adamw_init(params)
    state = {"params": params, "opt": opt_state}
    if compress:
        state["err"] = init_error_state(params)
    return state, axes


def opt_axes(param_axes, compress: bool = False):
    ax = {"params": param_axes, "opt": {"m": param_axes, "v": param_axes, "step": ()}}
    if compress:
        ax["err"] = param_axes
    return ax


def make_train_step(cfg, optim_cfg: AdamWConfig, n_micro: int = 1, compress: bool = False):
    api = build(cfg)
    accum = accumulated_value_and_grad(api.loss_fn, n_micro)

    def train_step(state, batch):
        loss, metrics, grads = accum(state["params"], batch)
        new_state = dict(state)
        if compress:
            grads, new_state["err"] = compress_tree(grads, state["err"])
        params, opt, om = adamw_update(optim_cfg, state["params"], grads, state["opt"])
        new_state["params"] = params
        new_state["opt"] = opt
        out_metrics = {"loss": loss, **metrics, **om}
        return new_state, out_metrics

    return train_step


def make_prefill_step(cfg, max_seq: int | None = None):
    api = build(cfg)

    def prefill_step(params, batch):
        seq = batch["tokens"].shape[1]
        return api.prefill(params, batch, max_seq if max_seq is not None else seq)

    return prefill_step


def make_decode_step(cfg):
    api = build(cfg)

    def decode_step(params, token, cache):
        return api.decode_step(params, token, cache)

    return decode_step
