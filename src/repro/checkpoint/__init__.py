"""Checkpoint substrate (atomic, async, validated restore)."""

from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager"]
