"""Checkpoint manager: sharded npz + manifest, atomic, async, self-healing.

Fault-tolerance contract (DESIGN.md §6):
  * a checkpoint is VALID iff its manifest exists AND every shard file's
    crc32 matches — torn/partial writes can never be restored from;
  * writes go to ``step_XXXX.tmp/`` then a single atomic ``os.replace`` of
    the directory publishes the checkpoint;
  * ``save_async`` runs serialization off the training thread (double-
    buffered: at most one outstanding save, back-pressure beyond that);
  * ``restore_latest`` walks checkpoints newest-first and silently skips
    invalid ones (a crashed writer costs one checkpoint, not the run);
  * retention keeps the newest ``keep`` checkpoints.

On a multi-host deployment each host saves its addressable shards under
``host_<k>/`` with the same manifest semantics; this container is
single-host, so there is one shard dir.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib

import jax
import numpy as np

__all__ = ["CheckpointManager"]

_MANIFEST = "manifest.json"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for key, v in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v

    def fix(node):
        if not isinstance(node, dict):
            return node
        keys = list(node)
        if keys and all(k.isdigit() for k in keys):
            return [fix(node[str(i)]) for i in range(len(keys))]
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: threading.Thread | None = None
        self._lock = threading.Lock()
        self._write_lock = threading.Lock()

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, extra: dict | None = None) -> str:
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        return self._write(step, host, extra or {})

    def save_async(self, step: int, tree, extra: dict | None = None) -> None:
        host = jax.tree.map(lambda x: np.asarray(x), tree)  # device→host now
        with self._lock:
            if self._pending is not None:
                self._pending.join()  # dacpcheck: ignore[blocking] reason=back-pressure by design; the joined writer only takes _write_lock, never _lock
            t = threading.Thread(target=self._write, args=(step, host, extra or {}), daemon=True)
            t.start()
            self._pending = t

    def wait(self) -> None:
        with self._lock:
            if self._pending is not None:
                self._pending.join()  # dacpcheck: ignore[blocking] reason=wait() exists to block until the save lands; writer never takes _lock
                self._pending = None

    def _write(self, step: int, host_tree, extra: dict) -> str:
        with self._write_lock:
            return self._write_locked(step, host_tree, extra)  # dacpcheck: ignore[blocking] reason=shard I/O is the critical section _write_lock serializes; it is a leaf lock

    def _write_locked(self, step: int, host_tree, extra: dict) -> str:
        flat = _flatten(host_tree)
        final = os.path.join(self.dir, f"step_{step:010d}")
        if self._validate(final) is not None:
            return final  # idempotent: this step is already durably saved
        tmp = f"{final}.tmp{threading.get_ident()}"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        shards = {}
        # group arrays into shard files of ~256MB
        group: dict = {}
        gbytes = 0
        gi = 0

        def flush():
            nonlocal group, gbytes, gi
            if not group:
                return
            name = f"shard_{gi:05d}.npz"
            path = os.path.join(tmp, name)
            with open(path, "wb") as f:
                np.savez(f, **{k.replace("/", "¦"): v for k, v in group.items()})
            with open(path, "rb") as f:
                crc = zlib.crc32(f.read())
            shards[name] = {"keys": list(group), "crc32": crc}
            group = {}
            gbytes = 0
            gi += 1

        for k, v in flat.items():
            group[k] = v
            gbytes += v.nbytes
            if gbytes >= (256 << 20):
                flush()
        flush()
        manifest = {
            "step": step,
            "time": time.time(),
            "shards": shards,
            "extra": extra,
            "n_arrays": len(flat),
        }
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._retain()
        return final

    def _retain(self) -> None:
        cps = self.list_steps()
        for step in cps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{step:010d}"), ignore_errors=True)

    # ------------------------------------------------------------------ restore
    def list_steps(self) -> list:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and ".tmp" not in d:
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def _validate(self, path: str) -> dict | None:
        mf = os.path.join(path, _MANIFEST)
        if not os.path.exists(mf):
            return None
        try:
            with open(mf) as f:
                manifest = json.load(f)
            for name, info in manifest["shards"].items():
                p = os.path.join(path, name)
                with open(p, "rb") as f:
                    if zlib.crc32(f.read()) != info["crc32"]:
                        return None
            return manifest
        except Exception:
            return None

    def restore(self, step: int):
        path = os.path.join(self.dir, f"step_{step:010d}")
        manifest = self._validate(path)
        if manifest is None:
            raise FileNotFoundError(f"checkpoint step {step} missing or corrupt")
        flat = {}
        for name in manifest["shards"]:
            with np.load(os.path.join(path, name), allow_pickle=False) as z:
                for k in z.files:
                    flat[k.replace("¦", "/")] = z[k]
        return _unflatten(flat), manifest

    def restore_latest(self):
        """Newest *valid* checkpoint, or (None, None)."""
        for step in reversed(self.list_steps()):
            path = os.path.join(self.dir, f"step_{step:010d}")
            manifest = self._validate(path)
            if manifest is not None:
                tree, _ = self.restore(step)
                return tree, manifest
        return None, None
