"""Explicit collective patterns (shard_map) used beyond GSPMD's defaults.

  * ``seq_sharded_decode_attention`` — flash-decoding across devices: each
    shard of the "data" axis holds a slice of a long KV cache, computes
    partial (m, l, acc) with the decode kernel/XLA path, and the partials
    combine with one tiny psum — O(B·H·hd) bytes instead of re-gathering a
    multi-GB cache (the long_500k optimization, EXPERIMENTS.md §Perf).
  * ``compressed_psum`` — int8 wire-format gradient reduction for the slow
    ``pod`` axis (error feedback handled by the caller).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["partial_decode_attention", "seq_sharded_decode_attention", "compressed_psum"]

NEG_INF = -1e30


def partial_decode_attention(q, k, v, valid_len):
    """Partial softmax stats over a LOCAL kv shard.

    q: (B, KV, G, hd); k/v: (B, KV, Tlocal, hd).  Returns (m, l, acc) with
    shapes ((B,KV,G,1), (B,KV,G,1), (B,KV,G,hd)) — combinable across shards.
    """
    hd = q.shape[-1]
    t = k.shape[2]
    s = jnp.einsum("bngh,bnth->bngt", q, k).astype(jnp.float32) * hd**-0.5
    mask = (jnp.arange(t) < valid_len)[None, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    acc = jnp.einsum("bngt,bnth->bngh", p.astype(v.dtype), v).astype(jnp.float32)
    return m, l, acc


def seq_sharded_decode_attention(mesh, q, k, v, index, seq_axis: str = "data"):
    """Decode attention with the KV cache sharded over ``seq_axis``.

    k/v: (B, KV, T, hd) global with T sharded; q replicated over seq_axis.
    Combines shard partials with psum of (m-shifted l, acc) — the classic
    flash-decoding merge.
    """
    from jax.experimental.shard_map import shard_map

    t_global = k.shape[2]
    n_shards = mesh.shape[seq_axis]
    t_local = t_global // n_shards

    def local(q_l, k_l, v_l, index_l):
        shard = jax.lax.axis_index(seq_axis)
        start = shard * t_local
        # positions valid within this shard: global position < index+1
        valid = jnp.clip(index_l + 1 - start, 0, t_local)
        m, l, acc = partial_decode_attention(q_l, k_l, v_l, valid)
        m_glob = jax.lax.pmax(m, axis_name=seq_axis)
        corr = jnp.exp(m - m_glob)
        l_corr = l * corr
        acc_corr = acc * corr
        l_sum = jax.lax.psum(l_corr, axis_name=seq_axis)
        acc_sum = jax.lax.psum(acc_corr, axis_name=seq_axis)
        out = acc_sum / jnp.maximum(l_sum, 1e-30)
        return out.astype(q_l.dtype)

    other = tuple(a for a in mesh.axis_names if a != seq_axis)
    qspec = P()
    kvspec = P(None, None, seq_axis, None)
    _ = other
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(qspec, kvspec, kvspec, P()),
        out_specs=P(),
        check_rep=False,
    )(q, k, v, index)


def compressed_psum(mesh, x, axis: str = "pod"):
    """int8-wire psum across ``axis`` (per-tensor scale travels alongside)."""
    from jax.experimental.shard_map import shard_map

    def local(x_l):
        scale = jnp.maximum(jnp.max(jnp.abs(x_l)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x_l / scale), -127, 127).astype(jnp.int8)
        # int8 payload crosses the axis; accumulate in int32 to avoid overflow
        total = jax.lax.psum(q.astype(jnp.int32), axis_name=axis)
        scale_max = jax.lax.pmax(scale, axis_name=axis)
        return total.astype(jnp.float32) * scale_max

    return shard_map(local, mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False)(x)
