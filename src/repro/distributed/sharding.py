"""Logical-axis sharding rules with divisibility-aware fallback.

Models annotate every param/activation dim with a *logical* axis name
(see ``repro.models.layers``).  This module maps logical names to mesh axes
and builds ``NamedSharding``s / ``with_sharding_constraint``s.  An axis that
does not evenly divide a dim is dropped (replicated) for that tensor — the
property that lets ten heterogeneous architectures (MQA kv=1, odd vocabs,
38-layer hybrids) all lower on one production mesh.

The active (mesh, rules) pair is installed with ``use_mesh`` — model code
calls ``constrain`` unconditionally; outside a mesh context it is a no-op,
so the same model functions run on a laptop and on a 512-chip mesh.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["DEFAULT_RULES", "Rules", "use_mesh", "current_mesh", "constrain", "pspec_for", "sharding_for", "tree_shardings", "tree_pspecs"]

# logical axis -> tuple of mesh axes (tried in order, first that divides wins)
DEFAULT_RULES = {
    # params
    "embed": ("data",),          # FSDP / ZeRO-3
    "heads": ("model",),         # TP
    "kv_heads": ("model",),
    "head_dim": (),
    "ffn": ("model",),
    "experts": ("model",),       # EP
    "vocab": ("model",),
    "ssm_in": ("model",),
    "ssm_heads": ("model",),
    "state": (),
    "layers": (),
    "conv_k": (),
    # activations
    "act_batch": ("pod", "data"),
    "act_seq": (),
    "act_seq_sharded": ("data",),  # sequence parallelism (opt-in)
    "act_embed": (),
    "act_heads": ("model",),
    "act_ffn": ("model",),
    "act_experts": ("model",),
    "act_vocab": ("model",),
    # kv cache
    "cache_batch": ("pod", "data"),
    "cache_seq_long": ("data",),  # long-context: shard the cache over seq
}


class Rules(dict):
    def merged(self, overrides: dict | None) -> "Rules":
        r = Rules(self)
        if overrides:
            r.update(overrides)
        return r


_ctx = threading.local()


@contextmanager
def use_mesh(mesh: Mesh, rules: dict | None = None):
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, Rules(DEFAULT_RULES).merged(rules))
    try:
        with mesh:
            yield
    finally:
        _ctx.state = prev


def current_mesh():
    st = getattr(_ctx, "state", None)
    return st[0] if st else None


def _mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def pspec_for(logical_axes, shape, mesh: Mesh, rules: dict) -> P:
    """Build a PartitionSpec, dropping mesh axes that don't divide dims."""
    sizes = _mesh_axis_sizes(mesh)
    used: set = set()
    parts = []
    for dim, name in zip(shape, logical_axes):
        if name is None:
            parts.append(None)
            continue
        cand = rules.get(name, ())
        if isinstance(cand, str):
            cand = (cand,)
        picked = []
        prod = 1
        for ax in cand:
            if ax in used or ax not in sizes:
                continue
            if dim % (prod * sizes[ax]) == 0:
                picked.append(ax)
                prod *= sizes[ax]
        for ax in picked:
            used.add(ax)
        if not picked:
            parts.append(None)
        elif len(picked) == 1:
            parts.append(picked[0])
        else:
            parts.append(tuple(picked))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def sharding_for(logical_axes, shape, mesh: Mesh | None = None, rules: dict | None = None) -> NamedSharding:
    st = getattr(_ctx, "state", None)
    if mesh is None:
        mesh, rules = st
    elif rules is None:
        rules = st[1] if st else Rules(DEFAULT_RULES)
    return NamedSharding(mesh, pspec_for(logical_axes, shape, mesh, rules))


def constrain(x, logical_axes):
    """with_sharding_constraint by logical axes; no-op without a mesh ctx."""
    st = getattr(_ctx, "state", None)
    if st is None:
        return x
    mesh, rules = st
    spec = pspec_for(logical_axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_pspecs(axes_tree, params_tree, mesh: Mesh, rules: dict | None = None):
    rules = Rules(DEFAULT_RULES).merged(rules)

    def one(axes, p):
        if axes is None:
            return P()
        return pspec_for(axes, np.shape(p), mesh, rules)

    return jax.tree.map(one, axes_tree, params_tree, is_leaf=lambda a: isinstance(a, tuple) or a is None)


def tree_shardings(axes_tree, params_tree, mesh: Mesh, rules: dict | None = None):
    specs = tree_pspecs(axes_tree, params_tree, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda s: isinstance(s, P))
