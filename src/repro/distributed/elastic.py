"""Elastic data-shard assignment via rendezvous (HRW) hashing.

Scientific corpora are file sets served by faird nodes; training hosts each
consume a disjoint shard.  Rendezvous hashing gives:

  * determinism — every host computes the same assignment with no
    coordinator;
  * minimal churn — when a host dies or joins, only the files owned by the
    affected host move (≈ 1/n of the data), which is what makes mid-run
    elasticity cheap;
  * weighting — hosts can advertise capacity weights (stragglers get less).

``plan_recovery`` diffs two assignments and reports exactly which files
must be re-read after a membership change.
"""

from __future__ import annotations

import hashlib

__all__ = ["assign_shards", "owner_of", "plan_recovery"]


def _score(key: str, host: str) -> float:
    h = hashlib.blake2b(f"{key}::{host}".encode(), digest_size=8).digest()
    v = int.from_bytes(h, "big") / float(1 << 64)
    return v


def owner_of(key: str, hosts: list, weights: dict | None = None) -> str:
    """Weighted HRW: draw u~U(0,1) per (key, host); cost = -ln(u)/w is
    Exp(w)-distributed, and the MINIMUM cost wins with P ∝ w."""
    import math

    best, best_cost = None, float("inf")
    for host in hosts:
        w = (weights or {}).get(host, 1.0)
        if w <= 0:
            continue
        cost = -math.log(max(_score(key, host), 1e-12)) / w
        if cost < best_cost:
            best, best_cost = host, cost
    if best is None:
        raise ValueError("no live hosts")
    return best


def assign_shards(files: list, hosts: list, weights: dict | None = None) -> dict:
    """-> {host: [files]} (deterministic, minimal-churn)."""
    out = {h: [] for h in hosts}
    for f in files:
        out[owner_of(f, hosts, weights)].append(f)
    return out


def plan_recovery(files: list, old_hosts: list, new_hosts: list, weights: dict | None = None) -> dict:
    """Files whose owner changed: {file: (old_owner|None, new_owner)}."""
    moved = {}
    for f in files:
        old = owner_of(f, old_hosts, weights) if old_hosts else None
        new = owner_of(f, new_hosts, weights)
        if old != new:
            moved[f] = (old, new)
    return moved
