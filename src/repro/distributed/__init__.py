"""Distribution substrate: logical sharding rules, collectives, elasticity."""

from repro.distributed.sharding import (
    DEFAULT_RULES,
    Rules,
    constrain,
    current_mesh,
    pspec_for,
    sharding_for,
    tree_pspecs,
    tree_shardings,
    use_mesh,
)

__all__ = [
    "DEFAULT_RULES",
    "Rules",
    "constrain",
    "current_mesh",
    "pspec_for",
    "sharding_for",
    "tree_pspecs",
    "tree_shardings",
    "use_mesh",
]
