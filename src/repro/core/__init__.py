"""DACP protocol core: the paper's §III as a composable library.

Public surface:
    Schema / Field / dtypes      — scientific type system (§III-A eq.2)
    RecordBatch / Column         — columnar atomic transport unit beta_k
    StreamingDataFrame (SDF)     — D = <S, F> (§III-A eq.1)
    Expr / col / lit             — serializable predicates & projections
    Dag / Node                   — COOK task graphs G=(V,E) (§III-B)
    optimize / required_columns  — predicate & projection pushdown
    plan / Plan / SubTask        — cross-domain decomposition (§III-D)
    TokenAuthority               — short-lived scoped access tokens (§III-C)
    parse / DacpUri              — dacp:// addressing (§III-C eq.3)
"""

from repro.core import dtypes
from repro.core.batch import Column, RecordBatch, concat_batches
from repro.core.dag import Dag, Node
from repro.core.errors import (
    DacpError,
    PermissionDenied,
    PlanError,
    ResourceNotFound,
    SchemaError,
    SubTaskFailed,
    TokenError,
    TransportError,
    TypeMismatchError,
)
from repro.core.expr import Expr, and_, col, lit, not_, or_
from repro.core.operators import MAP_REGISTRY, execute, get_map, register_map
from repro.core.planner import CLIENT_DOMAIN, Plan, SubTask, assign_domains, plan
from repro.core.pushdown import optimize, required_columns
from repro.core.schema import Field, Schema
from repro.core.sdf import SDF, StreamingDataFrame
from repro.core.tokens import Token, TokenAuthority
from repro.core.uri import DacpUri, format_uri, parse

__all__ = [
    "dtypes",
    "Column",
    "RecordBatch",
    "concat_batches",
    "Dag",
    "Node",
    "DacpError",
    "PermissionDenied",
    "PlanError",
    "ResourceNotFound",
    "SchemaError",
    "SubTaskFailed",
    "TokenError",
    "TransportError",
    "TypeMismatchError",
    "Expr",
    "and_",
    "col",
    "lit",
    "not_",
    "or_",
    "MAP_REGISTRY",
    "execute",
    "get_map",
    "register_map",
    "CLIENT_DOMAIN",
    "Plan",
    "SubTask",
    "assign_domains",
    "plan",
    "optimize",
    "required_columns",
    "Field",
    "Schema",
    "SDF",
    "StreamingDataFrame",
    "Token",
    "TokenAuthority",
    "DacpUri",
    "format_uri",
    "parse",
]
