"""Pluggable compute backends for the morsel executor (paper §III-D).

A *backend* supplies the vectorized kernels that operator evaluators run on
each morsel: predicate evaluation, filtering, and the fused filter+select
that the executor peepholes out of adjacent Filter→Select pairs.  Backends
are looked up in a **kernel registry** keyed ``(backend name, op name)``;
resolution falls back to the numpy reference kernels, so a backend only
overrides the ops it accelerates and everything else keeps reference
semantics bit-for-bit.

Two backends ship in-tree:

  * ``numpy``  — the reference implementation (always present).
  * ``pallas`` — dispatches eligible morsels to the JAX/Pallas kernels in
    ``repro.kernels`` (``filter_select.py`` via the jit wrappers in
    ``ops.py``).  A morsel is *eligible* for the fused kernel when the
    predicate is a simple ``col > literal`` comparison, every touched column
    is float32 with no validity mask, the threshold is exactly representable
    in float32, and the buffer is finite (the MXU one-hot matmuls would
    propagate NaN/Inf from unselected columns).  Anything else — including
    jax being absent entirely — falls back to the numpy kernel, so results
    are identical either way.  (Known normalization: ``-0.0`` compacts to
    ``+0.0`` through the MXU path.)

``get_backend("auto")`` selects pallas only when jax reports a real TPU;
interpret-mode Pallas on CPU is for correctness tests, not speed.
"""

from __future__ import annotations

import os
import threading
from typing import Callable

import numpy as np

from repro.core.batch import Column, RecordBatch
from repro.core.expr import Expr

__all__ = [
    "ComputeBackend",
    "KERNELS",
    "register_kernel",
    "get_backend",
    "available_backends",
    "BACKENDS",
]


# ---------------------------------------------------------------------------
# kernel registry
# ---------------------------------------------------------------------------
KERNELS: dict = {"numpy": {}, "pallas": {}}


def register_kernel(backend: str, op: str):
    """Register ``fn(backend_instance, ...)`` as ``op`` for ``backend``."""

    def deco(fn: Callable) -> Callable:
        KERNELS.setdefault(backend, {})[op] = fn
        return fn

    return deco


class ComputeBackend:
    """Kernel dispatch facade.  Instances are stateless and thread-safe."""

    name = "numpy"

    def kernel(self, op: str) -> Callable:
        impl = KERNELS.get(self.name, {}).get(op)
        if impl is None:
            impl = KERNELS["numpy"][op]
        return impl

    # -- morsel-level entry points (used by operator evaluators) ------------
    def eval_predicate(self, batch: RecordBatch, predicate: Expr) -> np.ndarray:
        return self.kernel("eval_predicate")(self, batch, predicate)

    def filter(self, batch: RecordBatch, predicate: Expr):
        """Apply a predicate; returns the surviving rows or ``None`` when the
        whole morsel is filtered out (no empty frames downstream)."""
        return self.kernel("filter")(self, batch, predicate)

    def filter_select(self, batch: RecordBatch, predicate: Expr, columns: list):
        """Fused filter + column projection (the executor's peephole)."""
        return self.kernel("filter_select")(self, batch, predicate, columns)


# ---------------------------------------------------------------------------
# numpy reference kernels
# ---------------------------------------------------------------------------
@register_kernel("numpy", "eval_predicate")
def _np_eval_predicate(bk, batch: RecordBatch, predicate: Expr) -> np.ndarray:
    return np.asarray(predicate.evaluate(batch), dtype=bool)


@register_kernel("numpy", "filter")
def _np_filter(bk, batch: RecordBatch, predicate: Expr):
    mask = _np_eval_predicate(bk, batch, predicate)
    if mask.all():
        return batch
    if not mask.any():
        return None
    return batch.filter(mask)


@register_kernel("numpy", "filter_select")
def _np_filter_select(bk, batch: RecordBatch, predicate: Expr, columns: list):
    out = _np_filter(bk, batch, predicate)
    return None if out is None else out.select(columns)


class NumpyBackend(ComputeBackend):
    name = "numpy"


# ---------------------------------------------------------------------------
# pallas backend
# ---------------------------------------------------------------------------
class PallasBackend(ComputeBackend):
    name = "pallas"
    tile = 256

    def __init__(self):
        self._kernel_mod = None
        self._disabled = False
        self._lock = threading.Lock()
        self.kernel_calls = 0  # observability: fused-kernel dispatch count

    def _ops(self):
        """Import the jit'd kernel wrappers once; a failed import (no jax)
        permanently disables dispatch and every kernel falls back to numpy."""
        if self._disabled:
            return None
        if self._kernel_mod is None:
            with self._lock:
                if self._kernel_mod is None and not self._disabled:
                    try:
                        from repro.kernels import ops as kernel_ops

                        self._kernel_mod = kernel_ops
                    except Exception:
                        self._disabled = True
        return self._kernel_mod


def _fused_plan(batch: RecordBatch, predicate: Expr, columns: list):
    """Eligibility check for the Pallas fused kernel.  Returns
    ``(pred_name, threshold, table_cols)`` or ``None`` (→ numpy fallback)."""
    if not (
        isinstance(predicate, Expr)
        and predicate.op == "gt"
        and isinstance(predicate.args[0], Expr)
        and predicate.args[0].op == "col"
        and isinstance(predicate.args[1], Expr)
        and predicate.args[1].op == "lit"
    ):
        return None
    threshold = predicate.args[1].args[0]
    if isinstance(threshold, bool) or not isinstance(threshold, (int, float)):
        return None
    if float(np.float32(threshold)) != float(threshold):
        return None  # f32 kernel compare would differ from the f64 reference
    pred_name = predicate.args[0].args[0]
    needed = [pred_name] + [c for c in columns if c != pred_name]
    schema = batch.schema
    for name in needed:
        if name not in schema:
            return None
        f = schema.field(name)
        if f.dtype.name != "float32":
            return None
        if batch.column(name).validity is not None:
            return None
    return pred_name, float(threshold), needed


@register_kernel("pallas", "filter_select")
def _pl_filter_select(bk: PallasBackend, batch: RecordBatch, predicate: Expr, columns: list):
    kernel_ops = bk._ops()
    plan = _fused_plan(batch, predicate, columns) if kernel_ops is not None else None
    if plan is None or batch.num_rows == 0:
        return _np_filter_select(bk, batch, predicate, columns)
    pred_name, threshold, needed = plan
    tile = bk.tile
    n = batch.num_rows
    n_pad = -(-n // tile) * tile
    table = np.full((n_pad, len(needed)), threshold, dtype=np.float32)
    for j, name in enumerate(needed):
        table[:n, j] = batch.column(name).values
    if not np.isfinite(table).all():
        return _np_filter_select(bk, batch, predicate, columns)
    sel_idx = tuple(needed.index(c) for c in columns)
    try:
        compacted, n_sel = kernel_ops.filter_select(table, 0, threshold, sel_idx, tile=tile)
    except Exception:
        return _np_filter_select(bk, batch, predicate, columns)
    bk.kernel_calls += 1
    if n_sel == 0:
        return None
    out_schema = batch.schema.select(columns)
    cols = [
        Column(f.dtype, values=np.ascontiguousarray(compacted[:, j]))
        for j, f in enumerate(out_schema)
    ]
    return RecordBatch(out_schema, cols)


@register_kernel("pallas", "filter")
def _pl_filter(bk: PallasBackend, batch: RecordBatch, predicate: Expr):
    # the unfused form is only kernel-eligible when EVERY column is float32
    return _pl_filter_select(bk, batch, predicate, list(batch.schema.names))


# ---------------------------------------------------------------------------
# backend selection
# ---------------------------------------------------------------------------
BACKENDS = {"numpy": NumpyBackend, "pallas": PallasBackend}
_instances: dict = {}
_instances_lock = threading.Lock()


def _jax_tpu() -> bool:
    try:
        import jax

        return jax.default_backend() == "tpu"
    except Exception:
        return False


def available_backends() -> list:
    out = ["numpy"]
    try:
        import importlib.util

        if importlib.util.find_spec("jax") is not None:
            out.append("pallas")
    except Exception:
        pass
    return out


def get_backend(name: str | None = None) -> ComputeBackend:
    """Resolve a backend by name.  ``auto`` (default, or env
    ``DACP_BACKEND``) picks pallas only on a real TPU; ``pallas`` without
    jax still resolves — its kernels just fall back to numpy."""
    name = name or os.environ.get("DACP_BACKEND", "auto")
    if name == "auto":
        name = "pallas" if _jax_tpu() else "numpy"
    if name not in BACKENDS:
        raise KeyError(f"unknown compute backend {name!r}; known: {sorted(BACKENDS)}")
    with _instances_lock:
        inst = _instances.get(name)
        if inst is None:
            inst = _instances[name] = BACKENDS[name]()
        return inst
