"""Pluggable compute backends for the morsel executor (paper §III-D).

A *backend* supplies the vectorized kernels that operator evaluators run on
each morsel: predicate evaluation, filtering, the fused filter+select that
the executor peepholes out of adjacent Filter→Select pairs, projection
arithmetic, and per-morsel segment reductions for partial aggregation.
Backends are looked up in a **kernel registry** keyed ``(backend name,
op name)``; resolution falls back to the numpy reference kernels, so a
backend only overrides the ops it accelerates and everything else keeps
reference semantics bit-for-bit.

Two backends ship in-tree:

  * ``numpy``  — the reference implementation (always present).
  * ``pallas`` — dispatches eligible morsels to the JAX/Pallas kernels in
    ``repro.kernels``.  Columns cross into the kernels as **int32
    bit-planes** (one plane per 4 bytes of width), so compaction and
    reduction matmuls move bit patterns exactly — the kernels are
    bit-identical to numpy for every fixed-width dtype, including
    ``-0.0``, NaN payloads, Inf, and full-range int64.  Eligibility is
    decided per morsel *and per column*; anything outside a kernel's
    envelope — var-width columns, validity masks, unsupported literal /
    column dtype pairings, or jax being absent entirely — falls back to
    the numpy kernel, so results are identical either way.

Dispatchable ops:

    filter_select   predicate ``col <cmp> lit`` with ``<cmp>`` in
                    {<, <=, >, >=, ==, !=}; predicate column float32 /
                    int32 / int64; projected columns any fixed-width dtype
    filter          the unfused form (projects every column)
    project         arithmetic Expr chains (+ - * / over float32 columns,
                    + - * over int32 columns, python-scalar literals)
    segment_reduce  per-group partial folds: count always, sum for integer
                    columns (8-bit-limb exact, wraparound-identical to
                    numpy), min/max for finite float32, int32-safe integer,
                    and the wide dtypes int64 / uint32 / uint64 / float64
                    via a two-word hi/lo compare — two masked-reduce kernel
                    passes over an order-preserving int64 key image (uint64:
                    top-bit flip; float64: sign-magnitude fold, NaN and
                    -0.0 ineligible), exact over the full 64-bit range;
                    float sums and mean partial sums fold through an
                    explicit **f64-accumulating reference path** (host-side
                    — kernel lanes are 32-bit — counted in
                    ``PallasBackend.f64_folds``) instead of silently falling
                    back; ≤ 256 groups per morsel

``get_backend("auto")`` selects pallas only when jax reports a real TPU;
interpret-mode Pallas on CPU is for correctness tests, not speed.
"""

from __future__ import annotations

import math
import threading
import warnings
from typing import Callable

import numpy as np

from repro.core.batch import Column, RecordBatch
from repro.core.env import env_str
from repro.core.expr import Expr

__all__ = [
    "ComputeBackend",
    "KERNELS",
    "register_kernel",
    "get_backend",
    "available_backends",
    "BACKENDS",
    "FUSED_INELIGIBLE",
    "FusedChainPlan",
    "plan_fused_chain",
]


# ---------------------------------------------------------------------------
# kernel registry
# ---------------------------------------------------------------------------
KERNELS: dict = {"numpy": {}, "pallas": {}}


def register_kernel(backend: str, op: str):
    """Register ``fn(backend_instance, ...)`` as ``op`` for ``backend``."""

    def deco(fn: Callable) -> Callable:
        KERNELS.setdefault(backend, {})[op] = fn
        return fn

    return deco


class ComputeBackend:
    """Kernel dispatch facade.  Instances are stateless and thread-safe."""

    name = "numpy"

    def kernel(self, op: str) -> Callable:
        impl = KERNELS.get(self.name, {}).get(op)
        if impl is None:
            impl = KERNELS["numpy"][op]
        return impl

    # -- morsel-level entry points (used by operator evaluators) ------------
    def eval_predicate(self, batch: RecordBatch, predicate: Expr) -> np.ndarray:
        return self.kernel("eval_predicate")(self, batch, predicate)

    def filter(self, batch: RecordBatch, predicate: Expr):
        """Apply a predicate; returns the surviving rows or ``None`` when the
        whole morsel is filtered out (no empty frames downstream)."""
        return self.kernel("filter")(self, batch, predicate)

    def filter_select(self, batch: RecordBatch, predicate: Expr, columns: list):
        """Fused filter + column projection (the executor's peephole)."""
        return self.kernel("filter_select")(self, batch, predicate, columns)

    def project(self, batch: RecordBatch, exprs: dict, out_schema):
        """Projection arithmetic over one morsel (shaped to ``out_schema``)."""
        return self.kernel("project")(self, batch, exprs, out_schema)

    def segment_reduce(self, gidx: np.ndarray, ngroups: int, specs: list, n_rows: int) -> dict:
        """Per-group partial reductions for one factorized morsel.

        ``specs`` is ``[(state_name, fn, values), ...]`` with ``fn`` in
        {count, sum, fsum, min, max} (``values`` is None for count; ``fsum``
        marks a float sum from a fresh state, foldable in the backend's
        f64-accumulating reference path).  Returns a dict mapping the state
        names the backend accelerated to per-group arrays of length
        ``ngroups``; callers scatter the rest with numpy.  The numpy
        backend accelerates nothing (``{}``)."""
        return self.kernel("segment_reduce")(self, gidx, ngroups, specs, n_rows)


# ---------------------------------------------------------------------------
# numpy reference kernels
# ---------------------------------------------------------------------------
@register_kernel("numpy", "eval_predicate")
def _np_eval_predicate(bk, batch: RecordBatch, predicate: Expr) -> np.ndarray:
    return np.asarray(predicate.evaluate(batch), dtype=bool)


@register_kernel("numpy", "filter")
def _np_filter(bk, batch: RecordBatch, predicate: Expr):
    mask = _np_eval_predicate(bk, batch, predicate)
    if mask.all():
        return batch
    if not mask.any():
        return None
    return batch.filter(mask)


@register_kernel("numpy", "filter_select")
def _np_filter_select(bk, batch: RecordBatch, predicate: Expr, columns: list):
    out = _np_filter(bk, batch, predicate)
    return None if out is None else out.select(columns)


@register_kernel("numpy", "project")
def _np_project(bk, batch: RecordBatch, exprs: dict, out_schema):
    from repro.core.operators import project_morsel

    return project_morsel(batch, exprs, out_schema)


@register_kernel("numpy", "segment_reduce")
def _np_segment_reduce(bk, gidx, ngroups, specs, n_rows) -> dict:
    return {}  # reference path: GroupState scatters with numpy ufuncs


class NumpyBackend(ComputeBackend):
    name = "numpy"


# ---------------------------------------------------------------------------
# int32 bit-plane column codec (host side of the pallas kernels)
# ---------------------------------------------------------------------------
_WIDE = {"float64", "int64", "uint64"}  # two planes: hi word, lo word
_NARROW_INT = {"int8", "int16", "uint8", "uint16", "bool"}  # widened exactly


def _plane_count(dtype_name: str) -> int:
    return 2 if dtype_name in _WIDE else 1


def _col_planes(values: np.ndarray, dtype_name: str) -> list:
    """Encode one fixed-width column into int32 bit-planes (lossless)."""
    v = np.ascontiguousarray(values)
    if dtype_name in _WIDE:
        b = v.view(np.int64)
        hi = (b >> 32).astype(np.int32)
        lo = (b & np.int64(0xFFFFFFFF)).astype(np.uint32).view(np.int32)
        return [hi, lo]
    if dtype_name == "float16":
        return [v.view(np.uint16).astype(np.int32)]
    if dtype_name in _NARROW_INT:
        return [v.astype(np.int32)]
    return [v.view(np.int32)]  # float32 / int32 / uint32


def _planes_to_values(planes: np.ndarray, dtype) -> np.ndarray:
    """Decode (n, planes) int32 back into the column's numpy dtype."""
    name = dtype.name
    if name in _WIDE:
        hi = planes[:, 0].astype(np.int64)
        lo = np.ascontiguousarray(planes[:, 1]).view(np.uint32).astype(np.int64)
        return ((hi << 32) | lo).view(dtype.np_dtype)
    if name == "float16":
        return planes[:, 0].astype(np.uint16).view(np.float16)
    if name in _NARROW_INT:
        return planes[:, 0].astype(dtype.np_dtype)
    return np.ascontiguousarray(planes[:, 0]).view(dtype.np_dtype)


# ---------------------------------------------------------------------------
# pallas backend
# ---------------------------------------------------------------------------
class PallasBackend(ComputeBackend):
    name = "pallas"
    tile = 256

    def __init__(self):
        self._kernel_mod = None
        self._disabled = False
        self._lock = threading.Lock()
        self.kernel_calls = 0  # observability: kernel dispatch count
        # float sums folded through the f64-accumulating reference path
        # (host-side; the kernels' 32-bit lanes cannot hold f64) — the
        # explicit, counted successor of the old silent fallback
        self.f64_folds = 0

    def _ops(self):
        """Import the jit'd kernel wrappers once; a failed import (no jax)
        permanently disables dispatch and every kernel falls back to numpy."""
        if self._disabled:
            return None
        if self._kernel_mod is None:
            with self._lock:
                if self._kernel_mod is None and not self._disabled:
                    try:
                        from repro.kernels import ops as kernel_ops

                        self._kernel_mod = kernel_ops
                    except Exception:
                        self._disabled = True
        return self._kernel_mod


# -- fused filter+select ----------------------------------------------------
_CMP_OPS = {"lt", "le", "gt", "ge", "eq", "ne"}
_PRED_KINDS = {"float32": "f32", "int32": "i32", "int64": "i64"}
_INT32_SIGN = 0x80000000


def _normalize_threshold(t, dtype_name: str, op: str):
    """Map a predicate literal onto kernel-comparable form for a column
    dtype.  Returns ``(kind, op, t_hi, t_lo)`` or ``None`` when the f32/int
    kernel comparison could not reproduce numpy's promotion semantics
    (e.g. a strong float64 scalar against a float32 column that is not
    exactly representable, or a float literal against an int64 column).
    Non-integer float literals against int32 columns rewrite to the
    equivalent integer comparison (``v > 2.5  ⇔  v > 2``)."""
    if isinstance(t, (bool, np.bool_)):
        return None
    if dtype_name == "float32":
        if isinstance(t, (int, float)) or (isinstance(t, np.floating) and t.dtype.itemsize <= 4):
            # weak python scalars (and <=32-bit float scalars) compare in
            # float32 under numpy-2 promotion — the kernel's native compare
            try:
                return ("f32", op, float(np.float32(t)), 0)
            except (OverflowError, ValueError):
                return None
        if isinstance(t, (np.integer, np.floating)):
            # strong 64-bit scalars promote the reference comparison to
            # float64; parity holds only for exactly-representable values
            thr = float(np.float32(t))
            return ("f32", op, thr, 0) if thr == t else None
        return None
    if dtype_name in ("int32", "int64"):
        if isinstance(t, np.uint64):
            return None  # numpy promotes int64 vs uint64 to float64
        if isinstance(t, (int, np.integer)):
            ti = int(t)
        elif isinstance(t, (float, np.floating)) and dtype_name == "int32":
            tf = float(t)
            if not np.isfinite(tf):
                return None
            if not tf.is_integer():
                if op in ("eq", "ne"):
                    return None  # constant mask; let numpy broadcast it
                # v <cmp> 2.5 is an integer comparison against floor(2.5)
                op = {"gt": "gt", "ge": "gt", "lt": "le", "le": "le"}[op]
                ti = int(np.floor(tf))
            else:
                ti = int(tf)
        else:
            return None  # float literals vs int64 compare in lossy float64
        lo, hi = (-(2**31), 2**31 - 1) if dtype_name == "int32" else (-(2**63), 2**63 - 1)
        if not (lo <= ti <= hi):
            return None  # reference raises (weak) or promotes (strong)
        if dtype_name == "int32":
            return ("i32", op, ti, 0)
        t_hi = ti >> 32
        t_lo = (ti & 0xFFFFFFFF) ^ _INT32_SIGN  # sign-flipped low word
        if t_lo >= 2**31:
            t_lo -= 2**32
        return ("i64", op, t_hi, t_lo)
    return None


def _fused_plan(batch: RecordBatch, predicate: Expr, columns: list):
    """Eligibility check for the Pallas fused kernel.  Returns
    ``(op, kind, t_hi, t_lo, pred_name)`` or ``None`` (→ numpy fallback)."""
    if not (
        isinstance(predicate, Expr)
        and predicate.op in _CMP_OPS
        and isinstance(predicate.args[0], Expr)
        and predicate.args[0].op == "col"
        and isinstance(predicate.args[1], Expr)
        and predicate.args[1].op == "lit"
    ):
        return None
    pred_name = predicate.args[0].args[0]
    schema = batch.schema
    if pred_name not in schema:
        return None
    pf = schema.field(pred_name)
    if pf.dtype.name not in _PRED_KINDS or batch.column(pred_name).validity is not None:
        return None
    norm = _normalize_threshold(predicate.args[1].args[0], pf.dtype.name, predicate.op)
    if norm is None:
        return None
    kind, op, t_hi, t_lo = norm
    for name in columns:
        if name not in schema:
            return None
        f = schema.field(name)
        if f.dtype.is_varwidth or batch.column(name).validity is not None:
            return None
    return op, kind, t_hi, t_lo, pred_name


@register_kernel("pallas", "filter_select")
def _pl_filter_select(bk: PallasBackend, batch: RecordBatch, predicate: Expr, columns: list):
    kernel_ops = bk._ops()
    plan = _fused_plan(batch, predicate, columns) if kernel_ops is not None else None
    if plan is None or batch.num_rows == 0:
        return _np_filter_select(bk, batch, predicate, columns)
    op, kind, t_hi, t_lo, pred_name = plan
    tile = bk.tile
    n = batch.num_rows
    n_pad = -(-n // tile) * tile
    out_schema = batch.schema.select(columns)
    pred_planes = _col_planes(batch.column(pred_name).values, batch.schema.field(pred_name).dtype.name)
    pred_arr = np.zeros((n_pad, len(pred_planes)), np.int32)
    for j, p in enumerate(pred_planes):
        pred_arr[:n, j] = p
    spans = []  # (plane start, plane count) per output column
    pos = 0
    for f in out_schema:
        k = _plane_count(f.dtype.name)
        spans.append((pos, k))
        pos += k
    table = np.zeros((n_pad, pos), np.int32)
    for f, (start, _k) in zip(out_schema, spans):
        for j, p in enumerate(_col_planes(batch.column(f.name).values, f.dtype.name)):
            table[:n, start + j] = p
    t_hi_bits = int(np.array([t_hi], np.float32).view(np.int32)[0]) if kind == "f32" else int(t_hi)
    scalars = np.asarray([n, t_hi_bits, int(t_lo)], np.int32)
    try:
        out, counts = kernel_ops.filter_select_planes(pred_arr, table, scalars, op, kind, tile=tile)
    except Exception:
        return _np_filter_select(bk, batch, predicate, columns)
    bk.kernel_calls += 1
    counts = np.asarray(counts)
    n_sel = int(counts.sum())
    if n_sel == 0:
        return None
    out = np.asarray(out)
    compact = np.concatenate([out[i * tile : i * tile + int(c)] for i, c in enumerate(counts) if c])
    cols = [
        Column(f.dtype, values=_planes_to_values(compact[:, start : start + k], f.dtype))
        for f, (start, k) in zip(out_schema, spans)
    ]
    return RecordBatch(out_schema, cols)


@register_kernel("pallas", "filter")
def _pl_filter(bk: PallasBackend, batch: RecordBatch, predicate: Expr):
    # the unfused form projects every column through the plane kernel
    return _pl_filter_select(bk, batch, predicate, list(batch.schema.names))


# -- fused project arithmetic ----------------------------------------------
_ARITH_F32 = {"add", "sub", "mul", "div"}
_ARITH_I32 = {"add", "sub", "mul"}  # int div/mod promote to float64 in numpy


def _contraction_safe(op: str, a, b) -> bool:
    """XLA's CPU backend contracts a float ``mul`` feeding ``add``/``sub``
    into a single-rounding FMA during LLVM codegen (nothing at the HLO level
    survives to prevent it), while numpy rounds the product separately — a
    1-ulp divergence whenever the product is inexact.  Only exact products
    are immune, so a float32 mul may sit directly under add/sub solely when
    one factor is a power-of-two literal (a mantissa-preserving scale).
    Division never contracts, and integer arithmetic is exact."""
    if op not in ("add", "sub"):
        return True
    for t in (a, b):
        if t[0] != "mul":
            continue
        if not any(
            s[0] == "lit" and _is_pow2_f32(s[1]) for s in (t[1], t[2])
        ):
            return False
    return True


def _is_pow2_f32(v) -> bool:
    v32 = float(np.float32(v))
    return v32 != 0.0 and math.isfinite(v32) and abs(math.frexp(v32)[0]) == 0.5


def _arith_descr(e, batch: RecordBatch, group: str, col_idx: dict):
    """Lower an Expr subtree to a kernel descriptor, interning column
    indices into ``col_idx``.  Returns None when any node falls outside the
    kernel envelope for ``group`` ("float32" | "int32")."""
    if not isinstance(e, Expr):
        return None
    if e.op == "col":
        name = e.args[0]
        if name not in batch.schema:
            return None
        f = batch.schema.field(name)
        if f.dtype.name != group or batch.column(name).validity is not None:
            return None
        if name not in col_idx:
            col_idx[name] = len(col_idx)
        return ("col", col_idx[name])
    if e.op == "lit":
        v = e.args[0]
        if isinstance(v, (bool, np.bool_)):
            return None
        if group == "float32":
            # weak scalars (and <=32-bit float scalars) keep f32 arithmetic
            if isinstance(v, (int, float)) or (isinstance(v, np.floating) and v.dtype.itemsize <= 4):
                return ("lit", float(v))
            return None
        if isinstance(v, (int, np.integer)) and not isinstance(v, np.uint64):
            vi = int(v)
            if isinstance(v, np.int64) or not (-(2**31) <= vi <= 2**31 - 1):
                return None  # would promote to int64 (or raise) in numpy
            return ("lit", vi)
        return None
    allowed = _ARITH_F32 if group == "float32" else _ARITH_I32
    if e.op not in allowed or len(e.args) != 2:
        return None
    a = _arith_descr(e.args[0], batch, group, col_idx)
    if a is None:
        return None
    b = _arith_descr(e.args[1], batch, group, col_idx)
    if b is None:
        return None
    if group == "float32" and not _contraction_safe(e.op, a, b):
        return None
    return (e.op, a, b)


@register_kernel("pallas", "project")
def _pl_project(bk: PallasBackend, batch: RecordBatch, exprs: dict, out_schema):
    from repro.core.operators import project_morsel

    kernel_ops = bk._ops()
    if kernel_ops is None or batch.num_rows == 0:
        return project_morsel(batch, exprs, out_schema)
    # plan each expression independently (per-column eligibility)
    groups: dict = {}  # group dtype -> (col_idx, [(out name, descr)])
    for name, e in exprs.items():
        f = out_schema.field(name)
        if f.dtype.name not in ("float32", "int32"):
            continue
        group = f.dtype.name
        col_idx = groups.setdefault(group, ({}, []))[0]
        snapshot = dict(col_idx)
        descr = _arith_descr(e, batch, group, col_idx)
        if descr is None or descr[0] in ("col", "lit"):
            col_idx.clear()
            col_idx.update(snapshot)  # drop columns interned by the failed plan
            continue
        groups[group][1].append((name, descr))
    planned = {name: None for g in groups.values() for name, _ in g[1]}
    if not planned:
        return project_morsel(batch, exprs, out_schema)
    n = batch.num_rows
    tile = bk.tile
    n_pad = -(-n // tile) * tile
    try:
        for group, (col_idx, outs) in groups.items():
            if not outs:
                continue
            np_dt = np.dtype(group)
            table = np.zeros((n_pad, max(1, len(col_idx))), np_dt)
            for cname, j in col_idx.items():
                table[:n, j] = batch.column(cname).values
            res = np.asarray(kernel_ops.project_tiles(table, tuple(d for _, d in outs), tile=tile))
            for j, (name, _d) in enumerate(outs):
                planned[name] = np.ascontiguousarray(res[:n, j])
    except Exception:
        return project_morsel(batch, exprs, out_schema)
    bk.kernel_calls += 1
    # assemble exactly like the reference evaluator: kernel outputs for the
    # planned exprs, numpy evaluation (+dtype coercion) for the rest
    new_cols = {}
    for name, e in exprs.items():
        f = out_schema.field(name)
        vals = planned.get(name)
        if vals is None:
            vals = np.asarray(e.evaluate(batch))
            if vals.ndim == 0:
                vals = np.full(batch.num_rows, vals[()])
            if not f.dtype.is_varwidth and vals.dtype != f.dtype.np_dtype:
                vals = vals.astype(f.dtype.np_dtype)
        new_cols[name] = Column.from_values(f.dtype, vals)
    cols = [new_cols[f.name] if f.name in new_cols else batch.column(f.name) for f in out_schema]
    return RecordBatch(out_schema, cols)


# -- segment reductions (partial aggregation) -------------------------------
_SEG_GROUP_CAP = 256
_SUM_LIMBS = 8  # 8-bit limbs, int64 coverage


def _sum_limbs(values: np.ndarray) -> list:
    v = values.astype(np.int64)
    limbs = [((v >> (8 * k)) & np.int64(0xFF)).astype(np.int32) for k in range(_SUM_LIMBS - 1)]
    limbs.append((v >> (8 * (_SUM_LIMBS - 1))).astype(np.int32))  # signed top limb
    return limbs


def _limbs_to_int64(sums: np.ndarray) -> np.ndarray:
    """(G, 8) int32 limb sums -> (G,) int64 (wraparound-identical to numpy)."""
    with np.errstate(over="ignore"):
        total = np.zeros(sums.shape[0], np.int64)
        for k in range(_SUM_LIMBS):
            total += sums[:, k].astype(np.int64) << np.int64(8 * k)
    return total


def _mm_eligible(values: np.ndarray, kind: str):
    """Kernel-ready min/max column or None.  float32 must be finite (XLA
    reduce NaN semantics are not IEEE-reliable); integers must fit int32."""
    dt = values.dtype
    if dt == np.float32:
        return values if np.isfinite(values).all() else None
    if dt.kind == "b" or (dt.kind == "i" and dt.itemsize <= 4) or (dt.kind == "u" and dt.itemsize <= 2):
        return values.astype(np.int32)
    return None


_I64_MAX = np.int64(2**63 - 1)
_I64_MIN = np.int64(-(2**63))
_U64_TOP = np.uint64(1 << 63)
_F64_LOW63 = np.int64(0x7FFFFFFFFFFFFFFF)


def _decode_i64(arr: np.ndarray, fn: str) -> np.ndarray:
    return arr  # empty-group sentinels (int64 extremes) ARE the identities


def _decode_u64(arr: np.ndarray, fn: str) -> np.ndarray:
    # inverse of the top-bit flip; the min sentinel int64-max decodes to
    # uint64-max and the max sentinel int64-min to 0 — the uint64 identities
    return arr.view(np.uint64) ^ _U64_TOP


def _decode_f64(arr: np.ndarray, fn: str) -> np.ndarray:
    # empty-group sentinels are unreachable from (non-NaN) float bits —
    # substitute the float identities before inverting the order map
    arr = arr.copy()
    if fn == "min":
        sent = arr == _I64_MAX
        inf = np.float64(np.inf)
    else:
        sent = arr == _I64_MIN
        inf = np.float64(-np.inf)
    bits = np.where(arr >= 0, arr, arr ^ _F64_LOW63)
    out = bits.view(np.float64).copy()
    out[sent] = inf
    return out


def _mm_wide_eligible(values: np.ndarray):
    """``(int64 order keys, decoder)`` for the two-word min/max path, or
    None.  The keys are an order-preserving int64 image of the column, fed
    through two ``segment_minmax_tiles`` passes (signed hi words, then
    sign-flipped lo words); the decoder maps group extremes (and the
    empty-group sentinels) back to the column dtype:

      * int64   — identity (sentinels are already the int64 identities)
      * uint32  — widens exactly into int64
      * uint64  — top-bit flip: ``u ^ 2^63`` viewed signed orders as uint64
      * float64 — sign-magnitude fold: non-negative bit patterns order as
        floats already; negative ones have all low 63 bits flipped.  NaN is
        ineligible (total order ≠ numpy's NaN propagation) and so is -0.0
        (bitwise total order would distinguish it from +0.0 where numpy's
        min/max result depends on operand order); ±Inf are fine.
    """
    dt = values.dtype
    if dt.kind == "i" and dt.itemsize == 8:
        return values, _decode_i64
    if dt.kind == "u" and dt.itemsize == 4:
        return values.astype(np.int64), _decode_i64
    if dt.kind == "u" and dt.itemsize == 8:
        return (values ^ _U64_TOP).view(np.int64), _decode_u64
    if dt == np.float64:
        if np.isnan(values).any() or ((values == 0.0) & np.signbit(values)).any():
            return None
        b = values.view(np.int64)
        return np.where(b >= 0, b, b ^ _F64_LOW63), _decode_f64
    return None


_LO_SIGN = np.uint32(0x80000000)


def _wide_words(v64: np.ndarray):
    """(hi, lo') int32 words of an int64 column whose lexicographic
    (signed hi, signed lo') order equals the int64 order: hi is the signed
    top word, lo' the sign-flipped low word."""
    hi = (v64 >> np.int64(32)).astype(np.int32)
    lo = ((v64 & np.int64(0xFFFFFFFF)).astype(np.uint32) ^ _LO_SIGN).view(np.int32)
    return hi, lo


def _wide_decode(hi: np.ndarray, lo_s: np.ndarray) -> np.ndarray:
    lo_u = (lo_s.view(np.uint32) ^ _LO_SIGN).astype(np.int64)
    return (hi.astype(np.int64) << np.int64(32)) | lo_u


@register_kernel("pallas", "segment_reduce")
def _pl_segment_reduce(bk: PallasBackend, gidx, ngroups, specs, n_rows) -> dict:
    kernel_ops = bk._ops()
    if (
        kernel_ops is None
        or ngroups == 0
        or ngroups > _SEG_GROUP_CAP
        or n_rows > kernel_ops.SUM_ROW_CAP
        or n_rows == 0
    ):
        return {}
    sums: list = []  # (state name, values)
    fsums: list = []  # (state name, f64 values) — host f64 reference path
    mms: dict = {"f32": [], "i32": []}  # kind -> [(state name, fn, col)]
    wides: list = []  # (state name, fn, int64 keys, decoder) — two-word min/max
    count_names: list = []
    for name, fn, values in specs:
        if fn == "count":
            count_names.append(name)
        elif fn == "fsum":
            fsums.append((name, values))
        elif fn == "sum":
            if values is not None and values.dtype.kind in "iub":
                sums.append((name, values))
        elif fn in ("min", "max") and values is not None:
            col = _mm_eligible(values, fn)
            if col is not None:
                mms["f32" if col.dtype == np.float32 else "i32"].append((name, fn, col))
            else:
                wide = _mm_wide_eligible(values)
                if wide is not None:
                    wides.append((name, fn, wide[0], wide[1]))
    if not (sums or count_names or mms["f32"] or mms["i32"] or wides or fsums):
        return {}
    tile = bk.tile
    n_pad = -(-n_rows // tile) * tile
    g_pad = -(-ngroups // 8) * 8
    g32 = np.zeros(n_pad, np.int32)
    g32[:n_rows] = np.asarray(gidx, np.int64)[:n_rows]
    out: dict = {}
    kernel_used = False
    try:
        if sums or count_names:
            limb_tbl = np.zeros((n_pad, max(1, _SUM_LIMBS * len(sums))), np.int32)
            for i, (_name, values) in enumerate(sums):
                for k, limb in enumerate(_sum_limbs(values)):
                    limb_tbl[:n_rows, _SUM_LIMBS * i + k] = limb
            s_res, c_res = kernel_ops.segment_sum_tiles(g32, limb_tbl, n_rows, g_pad, tile=tile)
            s_res, c_res = np.asarray(s_res), np.asarray(c_res)
            for i, (name, _values) in enumerate(sums):
                out[name] = _limbs_to_int64(s_res[:ngroups, _SUM_LIMBS * i : _SUM_LIMBS * (i + 1)])
            for name in count_names:
                out[name] = c_res[:ngroups].astype(np.int64)
            kernel_used = True
        for kind, entries in mms.items():
            if not entries:
                continue
            np_dt = np.float32 if kind == "f32" else np.int32
            tbl = np.zeros((n_pad, len(entries)), np_dt)
            for j, (_name, _fn, col) in enumerate(entries):
                tbl[:n_rows, j] = col
            fns = tuple(fn for _n, fn, _c in entries)
            res = np.asarray(kernel_ops.segment_minmax_tiles(g32, tbl, n_rows, g_pad, fns, tile=tile))
            for j, (name, _fn, _c) in enumerate(entries):
                out[name] = np.ascontiguousarray(res[:ngroups, j])
            kernel_used = True
        if wides:
            # two-word compare: pass 1 reduces the signed hi words; pass 2
            # reduces the sign-flipped lo words among only the rows whose hi
            # word equals their group's extreme (others masked to the
            # identity sentinel).  Lexicographic (hi, lo') == int64 order on
            # the order-preserving keys; each column's decoder maps the
            # extremes (and the empty-group sentinels) back to the source
            # dtype — int64/uint32 directly, uint64/float64 by inverting
            # their monotone int64 image (see ``_mm_wide_eligible``).
            fns = tuple(fn for _n, fn, _c, _d in wides)
            hi_tbl = np.zeros((n_pad, len(wides)), np.int32)
            lo_cols = []
            for j, (_name, _fn, col, _dec) in enumerate(wides):
                hi, lo = _wide_words(col)
                hi_tbl[:n_rows, j] = hi
                lo_cols.append((hi, lo))
            h_res = np.asarray(kernel_ops.segment_minmax_tiles(g32, hi_tbl, n_rows, g_pad, fns, tile=tile))
            lo_tbl = np.empty((n_pad, len(wides)), np.int32)
            for j, (_name, fn, _col, _dec) in enumerate(wides):
                sent = np.int32(2**31 - 1) if fn == "min" else np.int32(-(2**31))
                lo_tbl[:, j] = sent
                hi, lo = lo_cols[j]
                at_extreme = hi == h_res[:, j][g32[:n_rows]]
                lo_tbl[:n_rows, j] = np.where(at_extreme, lo, sent)
            l_res = np.asarray(kernel_ops.segment_minmax_tiles(g32, lo_tbl, n_rows, g_pad, fns, tile=tile))
            for j, (name, fn, _col, decode) in enumerate(wides):
                keys64 = _wide_decode(h_res[:ngroups, j], np.ascontiguousarray(l_res[:ngroups, j]))
                out[name] = decode(keys64, fn)
            kernel_used = True
        for name, values in fsums:
            # f64-accumulating reference path: bit-identical to the numpy
            # scatter because a fresh state's accumulators start at +0.0 and
            # np.add.at adds this morsel's values in the same row order
            acc = np.zeros(ngroups, np.float64)
            np.add.at(acc, np.asarray(gidx, np.int64), np.asarray(values, np.float64))
            out[name] = acc
    except Exception:
        return {}
    if kernel_used:
        bk.kernel_calls += 1
    if fsums:
        bk.f64_folds += len(fsums)
    return out


# ---------------------------------------------------------------------------
# whole-chain fused pipelines: one launch per morsel
# ---------------------------------------------------------------------------
# Sentinel returned by FusedChainPlan.run/.fold when THIS morsel falls
# outside the compiled envelope (validity mask appeared, row/group caps
# exceeded, non-finite min/max input); the caller falls back to the per-op
# path for that morsel only.
FUSED_INELIGIBLE = object()

_FLOAT_NAMES = {"float16", "float32", "float64"}


def _lit_value(v, group: str):
    """Literal eligibility for fused arithmetic — same envelope as
    ``_arith_descr`` (numpy promotion parity for the given group dtype)."""
    if isinstance(v, (bool, np.bool_)):
        return None
    if group == "float32":
        if isinstance(v, (int, float)) or (isinstance(v, np.floating) and v.dtype.itemsize <= 4):
            return float(v)
        return None
    if isinstance(v, (int, np.integer)) and not isinstance(v, np.uint64):
        vi = int(v)
        if isinstance(v, np.int64) or not (-(2**31) <= vi <= 2**31 - 1):
            return None
        return vi
    return None


def _lower_pred(pred, mapping: dict, src_schema):
    """Lower a filter predicate against SOURCE column names.  Returns
    ``(op, kind, t_hi_bits, t_lo, src_name)`` or None."""
    if not (
        isinstance(pred, Expr)
        and pred.op in _CMP_OPS
        and isinstance(pred.args[0], Expr)
        and pred.args[0].op == "col"
        and isinstance(pred.args[1], Expr)
        and pred.args[1].op == "lit"
    ):
        return None
    m = mapping.get(pred.args[0].args[0])
    if m is None or m[0] != "src":
        return None
    sname = m[1]
    dtn = src_schema.field(sname).dtype.name
    if dtn not in _PRED_KINDS:
        return None
    norm = _normalize_threshold(pred.args[1].args[0], dtn, pred.op)
    if norm is None:
        return None
    kind, op, t_hi, t_lo = norm
    t_hi_bits = int(np.array([t_hi], np.float32).view(np.int32)[0]) if kind == "f32" else int(t_hi)
    return op, kind, t_hi_bits, int(t_lo), sname


def _lower_arith_named(e, mapping: dict, src_schema, group: str):
    """Lower an Expr to a descriptor tree over SOURCE column names.
    Computed-of-computed inlines the earlier tree when the group matches:
    the stored f32/i32 column value IS the in-kernel subtree value (each op
    rounds in the group dtype either way), so inlining is exact."""
    if not isinstance(e, Expr):
        return None
    if e.op == "col":
        m = mapping.get(e.args[0])
        if m is None:
            return None
        if m[0] == "src":
            if src_schema.field(m[1]).dtype.name != group:
                return None
            return ("col", m[1])
        return m[2] if m[1] == group else None
    if e.op == "lit":
        v = _lit_value(e.args[0], group)
        return None if v is None else ("lit", v)
    allowed = _ARITH_F32 if group == "float32" else _ARITH_I32
    if e.op not in allowed or len(e.args) != 2:
        return None
    a = _lower_arith_named(e.args[0], mapping, src_schema, group)
    if a is None:
        return None
    b = _lower_arith_named(e.args[1], mapping, src_schema, group)
    if b is None:
        return None
    if group == "float32" and not _contraction_safe(e.op, a, b):
        return None
    return (e.op, a, b)


def _intern_tree(tree, idx: dict):
    """Replace source column names in a descriptor tree with table indices."""
    if tree[0] == "col":
        name = tree[1]
        if name not in idx:
            idx[name] = len(idx)
        return ("col", idx[name])
    if tree[0] == "lit":
        return tree
    return (tree[0], _intern_tree(tree[1], idx), _intern_tree(tree[2], idx))


def plan_fused_chain(specs: list, in_schema, agg=None, backend=None):
    """Compile a pipeline's op-spec chain into a :class:`FusedChainPlan`
    (one ``fused_chain_tiles`` launch per morsel), or None when any link
    falls outside the kernel envelope (→ the per-op path runs unchanged).

    ``specs`` is the executor's ``[(kind, args), ...]`` chain.  Eligible
    chains are any combination of at most one ``filter`` (predicate
    ``col <cmp> lit`` on a float32/int32/int64 source column), ``select``,
    and ``project`` (f32/i32 arithmetic or cast-free renames) — evaluated
    symbolically against SOURCE columns, so the kernel reads the original
    morsel regardless of where the filter sits in the chain.  With ``agg``
    (``(keys, aggs, mode, in_schema)``) the plan also folds the per-morsel
    partial aggregate in the same launch: counts, integer sums (8-bit-limb
    passthrough / 4-limb in-kernel for computed int32), f32 + narrow-int
    min/max, and float sums via compacted planes + the host's f64 fold.
    Float-keyed aggregates are ineligible (the pre-filter factorization
    could pick a different -0.0/NaN representative than the reference's
    post-filter one); wide min/max and var-width outputs are ineligible.
    """
    if backend is None or getattr(backend, "name", None) != "pallas":
        return None
    kernel_ops = backend._ops()
    if kernel_ops is None:
        return None
    mapping = {f.name: ("src", f.name) for f in in_schema}
    cur = in_schema
    filt = None
    for kind_, args in specs:
        if kind_ == "filter":
            if filt is not None:
                return None
            filt = _lower_pred(args[0], mapping, in_schema)
            if filt is None:
                return None
        elif kind_ == "select":
            cols = list(args[0])
            if any(c not in mapping for c in cols):
                return None
            mapping = {c: mapping[c] for c in cols}
            cur = cur.select(cols)
        elif kind_ == "project":
            exprs, out_schema = args
            new_map = {}
            for f in out_schema:
                e = exprs.get(f.name)
                if e is None:
                    m = mapping.get(f.name)
                    if m is None:
                        return None
                    new_map[f.name] = m
                    continue
                if isinstance(e, Expr) and e.op == "col":
                    m = mapping.get(e.args[0])
                    if m is None:
                        return None
                    src_dt = in_schema.field(m[1]).dtype.name if m[0] == "src" else m[1]
                    if src_dt != f.dtype.name:
                        return None  # dtype-coercing rename: outside the kernel
                    new_map[f.name] = m
                    continue
                if f.dtype.name not in ("float32", "int32"):
                    return None
                tree = _lower_arith_named(e, mapping, in_schema, f.dtype.name)
                if tree is None or tree[0] in ("col", "lit"):
                    return None
                new_map[f.name] = ("arith", f.dtype.name, tree)
            mapping = new_map
            cur = out_schema
        else:
            return None  # map / probe break the fusable chain
    if filt is None and agg is None:
        return None
    if not cur.fields:
        return None

    # -- assemble the kernel input/output layout --------------------------
    f_trees: dict = {}  # name-tree -> index among f32 computed columns
    i_trees: dict = {}
    pass_fields: list = []  # (src name, dtype, plane start, plane count)
    pass_pos = 0

    def _computed(m):
        _tag, group, tree = m
        trees = f_trees if group == "float32" else i_trees
        if tree not in trees:
            trees[tree] = len(trees)
        return ("f32" if group == "float32" else "i32", trees[tree])

    def _pass_ref(sname, dtype):
        nonlocal pass_pos
        for s, dt, start, k in pass_fields:
            if s == sname:
                return ("pass", start, k, dt)
        k = _plane_count(dtype.name)
        pass_fields.append((sname, dtype, pass_pos, k))
        ref = ("pass", pass_pos, k, dtype)
        pass_pos += k
        return ref

    out_decode = None
    key_srcs: list = []
    gcnt_states: list = []
    limb_srcs: list = []
    csum_states: list = []
    mmf: list = []
    mmi: list = []
    fsums: list = []
    if agg is None:
        out_decode = []
        for f in cur:
            m = mapping[f.name]
            if m[0] == "src":
                if f.dtype.is_varwidth:
                    return None
                out_decode.append((f, _pass_ref(m[1], f.dtype)))
            else:
                out_decode.append((f, _computed(m)))
    else:
        keys, aggs, mode, agg_schema = agg
        for k in keys:
            m = mapping.get(k)
            if m is None or m[0] != "src":
                return None
            if in_schema.field(m[1]).dtype.name in _FLOAT_NAMES:
                return None
            key_srcs.append((k, m[1]))

        def _fsum_ref(m):
            if m[0] == "src":
                dt = in_schema.field(m[1]).dtype
                return None if dt.is_varwidth else _pass_ref(m[1], dt)
            return _computed(m)

        for out, spec in aggs.items():
            fn = spec["fn"]
            if fn == "count":
                if mode == "final":
                    m = mapping.get(out)
                    if m is None or m[0] != "src":
                        return None
                    limb_srcs.append((out, m[1]))
                else:
                    gcnt_states.append(out)
            elif fn == "mean":
                psrc = f"{out}__psum" if mode == "final" else spec.get("column")
                m = mapping.get(psrc)
                if m is None:
                    return None
                r = _fsum_ref(m)
                if r is None:
                    return None
                fsums.append((f"{out}__psum", r))
                if mode == "final":
                    m2 = mapping.get(f"{out}__pcnt")
                    if m2 is None or m2[0] != "src":
                        return None
                    limb_srcs.append((f"{out}__pcnt", m2[1]))
                else:
                    gcnt_states.append(f"{out}__pcnt")
            elif fn == "sum":
                src = out if mode == "final" else spec.get("column")
                m = mapping.get(src)
                if m is None:
                    return None
                if m[0] == "src":
                    dt = in_schema.field(m[1]).dtype.np_dtype
                    if dt.kind in "iub":
                        limb_srcs.append((out, m[1]))
                    elif dt.kind == "f":
                        fsums.append((out, _pass_ref(m[1], in_schema.field(m[1]).dtype)))
                    else:
                        return None
                elif m[1] == "int32":
                    csum_states.append((out, _computed(m)[1]))
                else:
                    fsums.append((out, _computed(m)))
            elif fn in ("min", "max"):
                src = out if mode == "final" else spec.get("column")
                m = mapping.get(src)
                if m is None or m[0] != "src":
                    return None
                dt = in_schema.field(m[1]).dtype.np_dtype
                if dt == np.float32:
                    mmf.append((out, fn, m[1]))
                elif dt.kind == "b" or (dt.kind == "i" and dt.itemsize <= 4) or (dt.kind == "u" and dt.itemsize <= 2):
                    mmi.append((out, fn, m[1]))
                else:
                    return None
            else:
                return None

    af_idx: dict = {}
    ai_idx: dict = {}
    descrs_f = tuple(_intern_tree(t, af_idx) for t, _j in sorted(f_trees.items(), key=lambda kv: kv[1]))
    descrs_i = tuple(_intern_tree(t, ai_idx) for t, _j in sorted(i_trees.items(), key=lambda kv: kv[1]))
    af_cols = [s for s, _ in sorted(af_idx.items(), key=lambda kv: kv[1])]
    ai_cols = [s for s, _ in sorted(ai_idx.items(), key=lambda kv: kv[1])]
    checked = {s for s, _dt, _p, _k in pass_fields} | set(af_cols) | set(ai_cols)
    checked |= {s for _st, s in limb_srcs} | {s for _st, _fn, s in mmf} | {s for _st, _fn, s in mmi}
    if filt is not None:
        checked.add(filt[4])
    return FusedChainPlan(
        backend,
        kernel_ops,
        filt=filt,
        out_schema=cur if agg is None else None,
        out_decode=out_decode,
        agg=None if agg is None else (list(agg[0]), dict(agg[1]), agg[2], agg[3]),
        key_srcs=key_srcs,
        gcnt_states=gcnt_states,
        limb_srcs=limb_srcs,
        csum_states=csum_states,
        mmf=mmf,
        mmi=mmi,
        fsums=fsums,
        pass_fields=pass_fields,
        pass_width=pass_pos,
        descrs_f=descrs_f,
        descrs_i=descrs_i,
        af_cols=af_cols,
        ai_cols=ai_cols,
        checked_cols=sorted(checked),
    )


class FusedChainPlan:
    """Runtime for a compiled device-resident pipeline (see
    :func:`plan_fused_chain`).  ``run`` streams one morsel through the
    filter/project chain; ``fold`` additionally produces the per-morsel
    partial ``GroupState`` — byte-identical to the reference per-op fold.
    ``stage`` pre-uploads a morsel's kernel inputs (double buffering: the
    H2D transfer of morsel *i+1* overlaps the compute of morsel *i*);
    staged buffers are torn down by ``clear_staged`` on pipeline exit or
    cancel.  Per-morsel envelope violations return ``FUSED_INELIGIBLE``."""

    def __init__(
        self,
        backend,
        kernel_ops,
        *,
        filt,
        out_schema,
        out_decode,
        agg,
        key_srcs,
        gcnt_states,
        limb_srcs,
        csum_states,
        mmf,
        mmi,
        fsums,
        pass_fields,
        pass_width,
        descrs_f,
        descrs_i,
        af_cols,
        ai_cols,
        checked_cols,
    ):
        self._bk = backend
        self._kernel_ops = kernel_ops
        self._tile = backend.tile
        if filt is None:
            self._op, self._kind, self._t_hi, self._t_lo, self._pred_src = "gt", "none", 0, 0, None
        else:
            self._op, self._kind, self._t_hi, self._t_lo, self._pred_src = filt
        self._out_schema = out_schema
        self._out_decode = out_decode
        if agg is None:
            self._agg_keys = self._aggs = self._mode = self._agg_schema = None
        else:
            self._agg_keys, self._aggs, self._mode, self._agg_schema = agg
        self._key_srcs = key_srcs
        self._gcnt_states = gcnt_states
        self._limb_srcs = limb_srcs
        self._csum_states = csum_states
        self._mmf = mmf
        self._mmi = mmi
        self._fsums = fsums
        self._pass_fields = pass_fields
        self._dp = max(1, pass_width)
        self._limb_base = max(1, _SUM_LIMBS * len(limb_srcs))
        self._descrs_f = descrs_f
        self._descrs_i = descrs_i
        self._nf = len(descrs_f)
        self._csums = tuple(idx for _state, idx in csum_states)
        self._fns_f = tuple(fn for _s, fn, _c in mmf) or ("min",)
        self._fns_i = tuple(fn for _s, fn, _c in mmi) or ("min",)
        self._af_cols = af_cols
        self._ai_cols = ai_cols
        self._with_gidx = bool(fsums)
        self._gidx_off = self._dp + len(descrs_f) + len(descrs_i)
        self._checked_cols = checked_cols
        self._sizer = None
        self._dev_idx = None
        self._dev = None
        self._dev_resolved = False
        self._staged: dict = {}
        self._stage_lock = threading.Lock()
        self._stage_closed = False

    # -- executor wiring ----------------------------------------------------
    def bind(self, sizer, device_index=None) -> None:
        """Attach the pipeline's stat sink and (optional) device pin."""
        self._sizer = sizer
        self._dev_idx = device_index

    def _bump(self, counter: str, k: int = 1) -> None:
        if self._sizer is not None:
            self._sizer.bump(counter, k)

    def _device(self):
        if self._dev_resolved:
            return self._dev
        self._dev_resolved = True
        if self._dev_idx is not None:
            try:
                import jax

                devs = jax.devices()
            except Exception:
                return None
            if 0 <= self._dev_idx < len(devs):
                self._dev = devs[self._dev_idx]
            else:
                warnings.warn(
                    f"DACP_DEVICES index {self._dev_idx} out of range "
                    f"({len(devs)} jax devices); staging to the default device",
                    stacklevel=2,
                )
        return self._dev

    # -- per-morsel envelope ------------------------------------------------
    def _pad(self, n: int) -> int:
        return -(-n // self._tile) * self._tile

    def _morsel_ok(self, batch: RecordBatch) -> bool:
        n = batch.num_rows
        if n == 0 or n > self._kernel_ops.SUM_ROW_CAP:
            return False
        for name in self._checked_cols:
            if batch.column(name).validity is not None:
                return False
        return True

    # -- double-buffered uploads ---------------------------------------------
    def stage(self, batch: RecordBatch) -> None:
        """Begin the async H2D upload of ``batch``'s kernel inputs (jax
        device transfers are async: they overlap the previous morsel's
        compute).  run/fold pops the staged buffers by batch identity."""
        if self._stage_closed or not self._morsel_ok(batch):
            return
        try:
            import jax
        except Exception:
            return
        arrs = self._encode(batch)
        dev = self._device()
        try:
            put = {k: (jax.device_put(v, dev) if dev is not None else jax.device_put(v)) for k, v in arrs.items()}
        except Exception:
            return
        with self._stage_lock:
            if self._stage_closed:  # raced a CANCEL teardown: drop, don't leak
                return
            self._staged[id(batch)] = (batch.num_rows, put)

    def _take_staged(self, batch: RecordBatch):
        with self._stage_lock:
            entry = self._staged.pop(id(batch), None)
        if entry is None or entry[0] != batch.num_rows:
            return None
        return entry[1]

    def clear_staged(self) -> None:
        """Drop every in-flight staged buffer and refuse new ones (pipeline
        exit / CANCEL): a worker racing the teardown inside the source lock
        must not re-stage after the sweep."""
        with self._stage_lock:
            self._stage_closed = True
            self._staged.clear()

    @property
    def staged_count(self) -> int:
        with self._stage_lock:
            return len(self._staged)

    # -- host-side encode / decode -------------------------------------------
    def _encode(self, batch: RecordBatch) -> dict:
        n = batch.num_rows
        n_pad = self._pad(n)
        sch = batch.schema
        if self._kind == "none":
            pred = np.zeros((n_pad, 1), np.int32)
        else:
            planes = _col_planes(batch.column(self._pred_src).values, sch.field(self._pred_src).dtype.name)
            pred = np.zeros((n_pad, len(planes)), np.int32)
            for j, p in enumerate(planes):
                pred[:n, j] = p
        pass_tbl = np.zeros((n_pad, self._dp), np.int32)
        for s, dtype, start, _k in self._pass_fields:
            for j, p in enumerate(_col_planes(batch.column(s).values, dtype.name)):
                pass_tbl[:n, start + j] = p
        limb = np.zeros((n_pad, self._limb_base), np.int32)
        for i, (_state, s) in enumerate(self._limb_srcs):
            for k, plane in enumerate(_sum_limbs(np.asarray(batch.column(s).values))):
                limb[:n, _SUM_LIMBS * i + k] = plane
        mmf = np.zeros((n_pad, max(1, len(self._mmf))), np.float32)
        for j, (_state, _fn, s) in enumerate(self._mmf):
            mmf[:n, j] = batch.column(s).values
        mmi = np.zeros((n_pad, max(1, len(self._mmi))), np.int32)
        for j, (_state, _fn, s) in enumerate(self._mmi):
            mmi[:n, j] = np.asarray(batch.column(s).values).astype(np.int32)
        af = np.zeros((n_pad, max(1, len(self._af_cols))), np.float32)
        for j, s in enumerate(self._af_cols):
            af[:n, j] = batch.column(s).values
        ai = np.zeros((n_pad, max(1, len(self._ai_cols))), np.int32)
        for j, s in enumerate(self._ai_cols):
            ai[:n, j] = batch.column(s).values
        return {"pred": pred, "pass": pass_tbl, "limb": limb, "mmf": mmf, "mmi": mmi, "af": af, "ai": ai}

    def _compact(self, ctab: np.ndarray, counts: np.ndarray) -> np.ndarray:
        t = self._tile
        parts = [ctab[i * t : i * t + int(c)] for i, c in enumerate(counts) if c]
        return np.concatenate(parts) if parts else ctab[:0]

    def _decode_ref(self, compact: np.ndarray, ref):
        tag = ref[0]
        if tag == "pass":
            _t, start, k, dtype = ref
            return _planes_to_values(compact[:, start : start + k], dtype)
        off = self._dp + ref[1] if tag == "f32" else self._dp + self._nf + ref[1]
        col = np.ascontiguousarray(compact[:, off])
        return col.view(np.float32) if tag == "f32" else col

    def _launch(self, arrs: dict, gidx: np.ndarray, n: int, segmented: bool, ngroups: int):
        scalars = np.asarray([n, self._t_hi, self._t_lo, 0], np.int32)
        return self._kernel_ops.fused_chain_tiles(
            scalars,
            arrs["pred"],
            gidx,
            arrs["pass"],
            arrs["limb"],
            arrs["mmf"],
            arrs["mmi"],
            arrs["af"],
            arrs["ai"],
            op=self._op,
            kind=self._kind,
            descrs_f=self._descrs_f,
            descrs_i=self._descrs_i,
            csums=self._csums,
            fns_f=self._fns_f,
            fns_i=self._fns_i,
            with_gidx=self._with_gidx,
            segmented=segmented,
            ngroups=ngroups,
            tile=self._tile,
        )

    # -- streaming chain ------------------------------------------------------
    def run(self, batch: RecordBatch):
        """filter → project → select in one launch.  Returns the output
        morsel, None (fully filtered), or ``FUSED_INELIGIBLE``."""
        staged = self._take_staged(batch)
        if not self._morsel_ok(batch):
            return FUSED_INELIGIBLE
        arrs = staged if staged is not None else self._encode(batch)
        n = batch.num_rows
        gidx = np.zeros(self._pad(n), np.int32)
        try:
            out = self._launch(arrs, gidx, n, segmented=False, ngroups=8)
        except Exception:
            return FUSED_INELIGIBLE
        ctab, counts = np.asarray(out[0]), np.asarray(out[1])
        self._bump("fused_launches")
        if staged is not None:
            self._bump("transfers_overlapped")
        if int(counts.sum()) == 0:
            return None
        compact = self._compact(ctab, counts)
        cols = []
        for f, ref in self._out_decode:
            vals = self._decode_ref(compact, ref)
            cols.append(Column(f.dtype, values=vals) if ref[0] == "pass" else Column.from_values(f.dtype, vals))
        return RecordBatch(self._out_schema, cols)

    # -- aggregate fold --------------------------------------------------------
    def fold(self, batch: RecordBatch):
        """Per-morsel partial aggregate in one launch.  Returns a
        ``GroupState`` byte-identical to the reference per-op fold over the
        filtered morsel, None (no surviving rows), or ``FUSED_INELIGIBLE``.
        Group ids come from factorizing the PRE-filter morsel; the kernel's
        per-group minimum surviving row index reorders the survivors into
        first-seen-filtered order, matching the reference interning."""
        staged = self._take_staged(batch)
        if not self._morsel_ok(batch):
            return FUSED_INELIGIBLE
        for _state, _fn, s in self._mmf:
            if not np.isfinite(batch.column(s).values).all():
                return FUSED_INELIGIBLE
        from repro.core.operators import GroupState
        from repro.core.schema import Field, Schema

        keys = [k for k, _s in self._key_srcs]
        if all(k == s for k, s in self._key_srcs):
            kb = batch
        else:
            fields = [Field(k, batch.schema.field(s).dtype) for k, s in self._key_srcs]
            kb = RecordBatch(Schema(fields), [batch.column(s) for _k, s in self._key_srcs])
        tmp = GroupState(keys, {}, self._mode, kb.schema, vectorized=True)
        gidx_full = tmp._factorize(kb)
        ng = len(tmp.gids)
        if ng == 0 or ng > _SEG_GROUP_CAP:
            return FUSED_INELIGIBLE
        g_pad = max(8, -(-ng // 8) * 8)
        arrs = staged if staged is not None else self._encode(batch)
        n = batch.num_rows
        g32 = np.zeros(self._pad(n), np.int32)
        g32[:n] = gidx_full
        try:
            out = self._launch(arrs, g32, n, segmented=True, ngroups=g_pad)
        except Exception:
            return FUSED_INELIGIBLE
        ctab, counts, gsum, gcnt, gmmf, gmmi, gfirst = [np.asarray(o) for o in out]
        self._bump("fused_launches")
        if staged is not None:
            self._bump("transfers_overlapped")
        gcnt_v = gcnt[:ng]
        alive = np.flatnonzero(gcnt_v > 0)
        if alive.size == 0:
            return None
        perm = alive[np.argsort(gfirst[:ng][alive], kind="stable")]
        st = GroupState(
            self._agg_keys, self._aggs, self._mode, self._agg_schema, vectorized=True, backend=self._bk
        )
        st.key_rows = [tmp.key_rows[g] for g in perm]
        st.gids = {kt: i for i, kt in enumerate(st.key_rows)}
        acc: dict = {}
        for state in self._gcnt_states:
            acc[state] = gcnt_v[perm].astype(np.int64)
        for i, (state, _s) in enumerate(self._limb_srcs):
            acc[state] = _limbs_to_int64(gsum[:, _SUM_LIMBS * i : _SUM_LIMBS * (i + 1)][perm])
        base = self._limb_base
        for j, (state, _idx) in enumerate(self._csum_states):
            s4 = gsum[perm, base + 4 * j : base + 4 * (j + 1)].astype(np.int64)
            acc[state] = s4[:, 0] + (s4[:, 1] << 8) + (s4[:, 2] << 16) + (s4[:, 3] << 24)
        for j, (state, _fn, _s) in enumerate(self._mmf):
            acc[state] = gmmf[perm, j].astype(np.float64)
        for j, (state, _fn, _s) in enumerate(self._mmi):
            acc[state] = gmmi[perm, j].astype(np.int64)
        if self._fsums:
            compact = self._compact(ctab, counts)
            g_sel = compact[:, self._gidx_off]
            for state, ref in self._fsums:
                vals = np.asarray(self._decode_ref(compact, ref), np.float64)
                accf = np.zeros(ng, np.float64)
                np.add.at(accf, g_sel, vals)
                acc[state] = accf[perm]
        for name, (_init, dt) in st._state_specs().items():
            st.acc[name] = np.ascontiguousarray(np.asarray(acc[name], dt))
        return st


# ---------------------------------------------------------------------------
# backend selection
# ---------------------------------------------------------------------------
BACKENDS = {"numpy": NumpyBackend, "pallas": PallasBackend}
_instances: dict = {}
_instances_lock = threading.Lock()


def _jax_tpu() -> bool:
    try:
        import jax

        return jax.default_backend() == "tpu"
    except Exception:
        return False


def available_backends() -> list:
    out = ["numpy"]
    try:
        import importlib.util

        if importlib.util.find_spec("jax") is not None:
            out.append("pallas")
    except Exception:
        pass
    return out


def get_backend(name: str | None = None) -> ComputeBackend:
    """Resolve a backend by name.  ``auto`` (default, or env
    ``DACP_BACKEND``) picks pallas only on a real TPU; ``pallas`` without
    jax still resolves — its kernels just fall back to numpy."""
    name = name or env_str("DACP_BACKEND")
    if name == "auto":
        name = "pallas" if _jax_tpu() else "numpy"
    if name not in BACKENDS:
        raise KeyError(f"unknown compute backend {name!r}; known: {sorted(BACKENDS)}")
    with _instances_lock:
        inst = _instances.get(name)
        if inst is None:
            inst = _instances[name] = BACKENDS[name]()
        return inst
