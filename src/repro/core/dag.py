"""COOK task DAGs  G = (V, E)   (paper §III-B).

Vertices are standardized *operators* (Filter, Select, Project, Map, ...);
edges are streaming SDF flows.  A DAG is pure data (JSON) — no executable
payload crosses the wire — which is what makes computation offload to a
remote data center safe and schedulable.

Node operator vocabulary (closed set, versioned):

    source    params: {uri}                      0 inputs
    filter    params: {predicate: Expr}          1 input
    select    params: {columns: [str]}           1 input
    project   params: {exprs: {name: Expr}, keep: bool}  1 input
    map       params: {fn: str, fn_params: {}}   1 input   (registered fn name)
    rebatch   params: {rows: int}                1 input
    limit     params: {n: int}                   1 input
    union     params: {}                         N inputs
    aggregate params: {keys: [str],              1 input
                       aggs: {out: {fn, column}},
                       mode: full|partial|final}
    join      params: {on: [str]}                2 inputs  (inner equi-join;
                                                 left = probe, right = build)
    exchange  params: {uri, token}               0 inputs  (planner-inserted pull edge)

``aggregate`` modes implement distributed partial aggregation: ``full`` is
the user-facing op; the optimizer may split it into per-branch ``partial``
aggregates (emitting decomposed state: sums + counts for mean) combined by
one ``final`` aggregate above the cross-domain merge, so exchanges carry
partial aggregates instead of raw rows.
"""

from __future__ import annotations

import itertools
import json
from dataclasses import dataclass, field

from repro.core.errors import PlanError
from repro.core.expr import Expr

__all__ = ["Node", "Dag", "OPS"]

OPS = {
    "source": (0, 0),
    "filter": (1, 1),
    "select": (1, 1),
    "project": (1, 1),
    "map": (1, 1),
    "rebatch": (1, 1),
    "limit": (1, 1),
    "union": (1, 64),
    "aggregate": (1, 1),
    "join": (2, 2),
    "exchange": (0, 0),
}

_counter = itertools.count()


def _fresh_id(op: str) -> str:
    return f"{op}_{next(_counter)}"


@dataclass
class Node:
    id: str
    op: str
    params: dict = field(default_factory=dict)
    inputs: list = field(default_factory=list)

    def to_json(self) -> dict:
        params = {}
        for k, v in self.params.items():
            if isinstance(v, Expr):
                params[k] = {"$expr": v.to_json()}
            elif isinstance(v, dict) and all(isinstance(x, Expr) for x in v.values()):
                params[k] = {"$exprmap": {n: e.to_json() for n, e in v.items()}}
            else:
                params[k] = v
        return {"id": self.id, "op": self.op, "params": params, "inputs": list(self.inputs)}

    @staticmethod
    def from_json(d: dict) -> "Node":
        params = {}
        for k, v in d.get("params", {}).items():
            if isinstance(v, dict) and "$expr" in v:
                params[k] = Expr.from_json(v["$expr"])
            elif isinstance(v, dict) and "$exprmap" in v:
                params[k] = {n: Expr.from_json(e) for n, e in v["$exprmap"].items()}
            else:
                params[k] = v
        return Node(id=d["id"], op=d["op"], params=params, inputs=list(d.get("inputs", [])))


class Dag:
    """A validated operator DAG with a single output node."""

    def __init__(self, nodes: dict, output: str):
        self.nodes: dict = dict(nodes)
        self.output = output
        self.validate()

    # -- construction helpers ---------------------------------------------------
    @staticmethod
    def build() -> "DagBuilder":
        return DagBuilder()

    def validate(self) -> None:
        if self.output not in self.nodes:
            raise PlanError(f"output node {self.output!r} missing")
        for n in self.nodes.values():
            if n.op not in OPS:
                raise PlanError(f"unknown operator {n.op!r} in node {n.id}")
            lo, hi = OPS[n.op]
            if not (lo <= len(n.inputs) <= hi):
                raise PlanError(f"node {n.id} op {n.op} takes [{lo},{hi}] inputs, got {len(n.inputs)}")
            for i in n.inputs:
                if i not in self.nodes:
                    raise PlanError(f"node {n.id} references missing input {i!r}")
        # acyclicity + reachability
        order = self.topological_order()
        reachable = self._reachable_from_output()
        unreachable = set(self.nodes) - reachable
        if unreachable:
            # prune silently: planner fragments legitimately drop nodes
            for u in unreachable:
                del self.nodes[u]
        assert order is not None

    def topological_order(self) -> list:
        indeg = {i: 0 for i in self.nodes}
        out_edges: dict = {i: [] for i in self.nodes}
        for n in self.nodes.values():
            for i in n.inputs:
                indeg[n.id] += 1
                out_edges[i].append(n.id)
        ready = sorted(i for i, d in indeg.items() if d == 0)
        order = []
        while ready:
            u = ready.pop()
            order.append(u)
            for v in out_edges[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    ready.append(v)
        if len(order) != len(self.nodes):
            raise PlanError("cycle detected in DAG")
        return order

    def _reachable_from_output(self) -> set:
        seen = set()
        stack = [self.output]
        while stack:
            u = stack.pop()
            if u in seen:
                continue
            seen.add(u)
            stack.extend(self.nodes[u].inputs)
        return seen

    # -- analysis ------------------------------------------------------------------
    def sources(self) -> list:
        return [n for n in self.nodes.values() if n.op in ("source", "exchange")]

    def consumers_of(self, node_id: str) -> list:
        return [n for n in self.nodes.values() if node_id in n.inputs]

    # -- wire -------------------------------------------------------------------------
    def to_json(self) -> dict:
        return {
            "version": 2,  # v2: aggregate/join joined the operator vocabulary
            "output": self.output,
            "nodes": [self.nodes[i].to_json() for i in self.topological_order()],
        }

    def to_bytes(self) -> bytes:
        return json.dumps(self.to_json(), separators=(",", ":")).encode()

    @staticmethod
    def from_json(d: dict) -> "Dag":
        nodes = {nd["id"]: Node.from_json(nd) for nd in d["nodes"]}
        return Dag(nodes, d["output"])

    @staticmethod
    def from_bytes(b: bytes) -> "Dag":
        return Dag.from_json(json.loads(b.decode()))

    def copy(self) -> "Dag":
        return Dag.from_json(self.to_json())


class DagBuilder:
    """Imperative builder used by the client's chainable API."""

    def __init__(self):
        self.nodes: dict = {}

    def add(self, op: str, params: dict | None = None, inputs: list | None = None, id: str | None = None) -> str:
        nid = id or _fresh_id(op)
        self.nodes[nid] = Node(id=nid, op=op, params=params or {}, inputs=list(inputs or []))
        return nid

    def source(self, uri: str) -> str:
        return self.add("source", {"uri": str(uri)})

    def finish(self, output: str) -> Dag:
        return Dag(self.nodes, output)
