"""Operator library + pull-based streaming executor (paper §III-B, §IV-B).

Every operator consumes and produces SDF batch streams.  Execution is
**lazy / pull-based (reverse supply)**: building an executor does no work;
iterating the *output* recursively pulls from inputs, activating upstream
operators one batch at a time — the paper's §III-D execution model.

``map`` operators reference functions from a **named registry** — the DAG
itself never carries code.  Each registered fn declares the columns it reads
and writes so the pushdown optimizer can reorder filters around it.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterator

import numpy as np

from repro.core.batch import Column, RecordBatch, concat_batches
from repro.core.dag import Dag, Node
from repro.core.dtypes import resolve as resolve_dtype
from repro.core.errors import PlanError, SchemaError
from repro.core.expr import Expr
from repro.core.schema import Field, Schema
from repro.core.sdf import StreamingDataFrame

__all__ = ["MapFn", "register_map", "get_map", "MAP_REGISTRY", "execute", "execute_node"]


# ---------------------------------------------------------------------------
# map-fn registry
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MapFn:
    name: str
    fn: Callable  # (RecordBatch, **params) -> RecordBatch
    schema_fn: Callable  # (Schema, **params) -> Schema
    reads: tuple  # column names read ("*" = all)
    writes: tuple  # column names written/created


MAP_REGISTRY: dict = {}


def register_map(name: str, reads=("*",), writes=()):
    def deco(fn):
        def default_schema(schema: Schema, **params) -> Schema:
            return schema

        schema_fn = getattr(fn, "schema_fn", default_schema)
        MAP_REGISTRY[name] = MapFn(name, fn, schema_fn, tuple(reads), tuple(writes))
        return fn

    return deco


def get_map(name: str) -> MapFn:
    try:
        return MAP_REGISTRY[name]
    except KeyError:
        raise PlanError(f"map fn {name!r} is not registered on this server") from None


# a few built-in maps used by the data pipeline and tests -------------------------
def _schema_add(name: str, dtype: str):
    def sf(schema: Schema, **params) -> Schema:
        out = name if "out" not in params else params["out"]
        f = Field(out, resolve_dtype(dtype))
        if out in schema:
            return schema  # replaced in-place by with_column
        return schema.append(f)

    return sf


def _blob_lengths(batch: RecordBatch, column: str, out: str = "nbytes") -> RecordBatch:
    c = batch.column(column)
    if c.dtype.is_varwidth:
        lens = (c.offsets[1:] - c.offsets[:-1]).astype(np.int64)
    else:
        lens = np.full(batch.num_rows, c.dtype.width, dtype=np.int64)
    return batch.with_column(Field(out, resolve_dtype("int64")), Column.from_values(resolve_dtype("int64"), lens))


_blob_lengths.schema_fn = _schema_add("nbytes", "int64")
register_map("blob_lengths", reads=("*",), writes=("nbytes",))(_blob_lengths)


def _lowercase(batch: RecordBatch, column: str) -> RecordBatch:
    c = batch.column(column)
    vals = [v.lower() if isinstance(v, str) else v for v in c.to_pylist()]
    return batch.with_column(batch.schema.field(column), Column.from_values(c.dtype, vals))


register_map("lowercase", reads=("*",), writes=())(_lowercase)


# ---------------------------------------------------------------------------
# per-node streaming evaluators
# ---------------------------------------------------------------------------
def _eval_filter(node: Node, ins: list) -> StreamingDataFrame:
    (src,) = ins
    pred: Expr = node.params["predicate"]

    def gen() -> Iterator[RecordBatch]:
        for b in src.iter_batches():
            mask = np.asarray(pred.evaluate(b), dtype=bool)
            if mask.all():
                yield b
            elif mask.any():
                yield b.filter(mask)
            # fully-masked batches are dropped (no empty frames on the wire)

    return StreamingDataFrame(src.schema, gen)


def _eval_select(node: Node, ins: list) -> StreamingDataFrame:
    (src,) = ins
    cols = list(node.params["columns"])
    schema = src.schema.select(cols)

    def gen():
        for b in src.iter_batches():
            yield b.select(cols)

    return StreamingDataFrame(schema, gen)


def _infer_project_schema(src_schema: Schema, exprs: dict, keep: bool) -> Schema:
    """Infer projection dtypes by evaluating on an empty batch (cheap, exact)."""
    from repro.core import dtypes as _dt

    empty = RecordBatch.empty(src_schema)
    fields = list(src_schema.fields) if keep else []
    names = {f.name for f in fields}
    for name, e in exprs.items():
        vals = np.asarray(e.evaluate(empty))
        if vals.ndim == 0:  # literal broadcast: dtype of the scalar
            vals = np.asarray([vals[()]])
        try:
            dt = _dt.from_numpy(vals.dtype)
        except KeyError:
            dt = _dt.STRING
        f = Field(name, dt)
        if name in names:
            fields[[x.name for x in fields].index(name)] = f
        else:
            fields.append(f)
            names.add(name)
    return Schema(fields)


def _eval_project(node: Node, ins: list) -> StreamingDataFrame:
    (src,) = ins
    exprs: dict = node.params["exprs"]
    keep: bool = bool(node.params.get("keep", True))

    schema_holder = {"schema": _infer_project_schema(src.schema, exprs, keep)}

    def _projected(b: RecordBatch):
        from repro.core import dtypes as _dt

        cols = []
        for name, e in exprs.items():
            vals = np.asarray(e.evaluate(b))
            if vals.ndim == 0:
                vals = np.full(b.num_rows, vals[()])
            dt = _dt.from_numpy(vals.dtype)
            cols.append((Field(name, dt), Column.from_values(dt, vals)))
        return cols

    def gen():
        for b in src.iter_batches():
            new_cols = _projected(b)
            if keep:
                out = b
                for f, c in new_cols:
                    out = out.with_column(f, c)
            else:
                out = RecordBatch(Schema([f for f, _ in new_cols]), [c for _, c in new_cols])
            schema_holder["schema"] = out.schema
            yield out

    return StreamingDataFrame(schema_holder["schema"], gen)


def _eval_map(node: Node, ins: list) -> StreamingDataFrame:
    (src,) = ins
    mf = get_map(node.params["fn"])
    fn_params = dict(node.params.get("fn_params", {}))
    schema = mf.schema_fn(src.schema, **fn_params)

    def gen():
        for b in src.iter_batches():
            yield mf.fn(b, **fn_params)

    return StreamingDataFrame(schema, gen)


def _eval_rebatch(node: Node, ins: list) -> StreamingDataFrame:
    (src,) = ins
    rows = int(node.params["rows"])
    if rows <= 0:
        raise PlanError("rebatch rows must be positive")

    def gen():
        pend: list = []
        pend_rows = 0
        for b in src.iter_batches():
            pend.append(b)
            pend_rows += b.num_rows
            while pend_rows >= rows:
                merged = concat_batches(pend)
                yield merged.slice(0, rows)
                rest = merged.slice(rows, merged.num_rows)
                pend = [rest] if rest.num_rows else []
                pend_rows = rest.num_rows
        if pend_rows:
            yield concat_batches(pend)

    return StreamingDataFrame(src.schema, gen)


def _eval_limit(node: Node, ins: list) -> StreamingDataFrame:
    (src,) = ins
    n = int(node.params["n"])

    def gen():
        seen = 0
        if n <= 0:
            return
        for b in src.iter_batches():
            if seen + b.num_rows >= n:
                yield b.slice(0, n - seen)  # no further upstream pulls
                return
            seen += b.num_rows
            yield b

    return StreamingDataFrame(src.schema, gen)


def _eval_union(node: Node, ins: list) -> StreamingDataFrame:
    schema = ins[0].schema
    for s in ins[1:]:
        if not s.schema.equals(schema):
            raise SchemaError("union over mismatched schemas")

    def gen():
        for s in ins:
            yield from s.iter_batches()

    return StreamingDataFrame(schema, gen)


_EVAL = {
    "filter": _eval_filter,
    "select": _eval_select,
    "project": _eval_project,
    "map": _eval_map,
    "rebatch": _eval_rebatch,
    "limit": _eval_limit,
    "union": _eval_union,
}


def execute_node(node: Node, inputs: list) -> StreamingDataFrame:
    try:
        fn = _EVAL[node.op]
    except KeyError:
        raise PlanError(f"operator {node.op!r} has no local evaluator") from None
    return fn(node, inputs)


def execute(dag: Dag, source_resolver: Callable[[Node], StreamingDataFrame]) -> StreamingDataFrame:
    """Wire the DAG into a lazy pull pipeline and return the output SDF.

    ``source_resolver`` materializes ``source`` / ``exchange`` leaves — the
    server resolves URIs against its catalog; the scheduler resolves exchanges
    against remote pulls.
    """
    materialized: dict = {}
    for nid in dag.topological_order():
        node = dag.nodes[nid]
        if node.op in ("source", "exchange"):
            materialized[nid] = source_resolver(node)
        else:
            materialized[nid] = execute_node(node, [materialized[i] for i in node.inputs])
    return materialized[dag.output]
