"""Operator library: morsel-pure evaluators + the reference pull driver
(paper §III-B, §IV-B).

The module is split in two layers since the executor refactor:

  * **morsel-pure functions** (``filter_morsel``, ``select_morsel``,
    ``project_morsel``, ``map_morsel``, ``join_probe_morsel``) — each maps
    one RecordBatch to at most one RecordBatch with no cross-batch state.
    They are the unit of work the morsel-driven parallel driver
    (``repro.core.executor``) hands to its workers, and they take a
    ``ComputeBackend`` so eligible morsels dispatch to Pallas kernels.
  * **streaming evaluators + ``execute``** — the reference lazy pull chain
    (reverse supply): building an executor does no work; iterating the
    output recursively pulls from inputs one batch at a time — the paper's
    §III-D execution model, single-threaded.  ``SDFEngine`` uses the
    parallel driver by default and keeps this path as the ``num_workers=0``
    reference/fallback.

``map`` operators reference functions from a **named registry** — the DAG
itself never carries code.  Each registered fn declares the columns it reads
and writes so the pushdown optimizer can reorder filters around it.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterator

import numpy as np

from repro.core.batch import Column, RecordBatch, concat_batches
from repro.core.dag import Dag, Node
from repro.core.dtypes import resolve as resolve_dtype
from repro.core.errors import PlanError, SchemaError
from repro.core.expr import Expr
from repro.core.schema import Field, Schema
from repro.core.sdf import StreamingDataFrame

__all__ = [
    "MapFn",
    "register_map",
    "get_map",
    "MAP_REGISTRY",
    "execute",
    "execute_node",
    "filter_morsel",
    "select_morsel",
    "project_morsel",
    "project_schema",
    "map_morsel",
    "join_schema",
    "build_join_table",
    "join_probe_indices",
    "join_probe_morsel",
    "GroupState",
    "agg_out_fields",
]


# ---------------------------------------------------------------------------
# map-fn registry
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MapFn:
    name: str
    fn: Callable  # (RecordBatch, **params) -> RecordBatch
    schema_fn: Callable  # (Schema, **params) -> Schema
    reads: tuple  # column names read ("*" = all)
    writes: tuple  # column names written/created


MAP_REGISTRY: dict = {}


def register_map(name: str, reads=("*",), writes=()):
    def deco(fn):
        def default_schema(schema: Schema, **params) -> Schema:
            return schema

        schema_fn = getattr(fn, "schema_fn", default_schema)
        MAP_REGISTRY[name] = MapFn(name, fn, schema_fn, tuple(reads), tuple(writes))
        return fn

    return deco


def get_map(name: str) -> MapFn:
    try:
        return MAP_REGISTRY[name]
    except KeyError:
        raise PlanError(f"map fn {name!r} is not registered on this server") from None


# a few built-in maps used by the data pipeline and tests -------------------------
def _schema_add(name: str, dtype: str):
    def sf(schema: Schema, **params) -> Schema:
        out = name if "out" not in params else params["out"]
        f = Field(out, resolve_dtype(dtype))
        if out in schema:
            return schema  # replaced in-place by with_column
        return schema.append(f)

    return sf


def _blob_lengths(batch: RecordBatch, column: str, out: str = "nbytes") -> RecordBatch:
    c = batch.column(column)
    if c.dtype.is_varwidth:
        lens = (c.offsets[1:] - c.offsets[:-1]).astype(np.int64)
    else:
        lens = np.full(batch.num_rows, c.dtype.width, dtype=np.int64)
    return batch.with_column(Field(out, resolve_dtype("int64")), Column.from_values(resolve_dtype("int64"), lens))


_blob_lengths.schema_fn = _schema_add("nbytes", "int64")
register_map("blob_lengths", reads=("*",), writes=("nbytes",))(_blob_lengths)


def _lowercase(batch: RecordBatch, column: str) -> RecordBatch:
    c = batch.column(column)
    vals = [v.lower() if isinstance(v, str) else v for v in c.to_pylist()]
    return batch.with_column(batch.schema.field(column), Column.from_values(c.dtype, vals))


register_map("lowercase", reads=("*",), writes=())(_lowercase)


# ---------------------------------------------------------------------------
# morsel-pure operator functions (shared by the pull chain and the parallel
# executor; each maps one batch -> one batch or None, no cross-batch state)
# ---------------------------------------------------------------------------
def filter_morsel(batch: RecordBatch, predicate: Expr, backend=None) -> RecordBatch | None:
    """Surviving rows of one morsel, or None when fully masked (no empty
    frames downstream).  ``backend`` dispatches eligible morsels to
    accelerator kernels; None means the numpy reference path."""
    if backend is not None:
        return backend.filter(batch, predicate)
    mask = np.asarray(predicate.evaluate(batch), dtype=bool)
    if mask.all():
        return batch
    if not mask.any():
        return None
    return batch.filter(mask)


def select_morsel(batch: RecordBatch, columns: list) -> RecordBatch:
    return batch.select(columns)


def map_morsel(batch: RecordBatch, mf: "MapFn", fn_params: dict) -> RecordBatch:
    return mf.fn(batch, **fn_params)


def project_morsel(batch: RecordBatch, exprs: dict, out_schema: Schema) -> RecordBatch:
    """Evaluate projection exprs against one morsel, shaping the output to a
    precomputed schema (dtype-coerced — morsel workers must all agree)."""
    new_cols = {}
    for name, e in exprs.items():
        vals = np.asarray(e.evaluate(batch))
        if vals.ndim == 0:
            vals = np.full(batch.num_rows, vals[()])
        f = out_schema.field(name)
        if not f.dtype.is_varwidth and vals.dtype != f.dtype.np_dtype:
            vals = vals.astype(f.dtype.np_dtype)
        new_cols[name] = Column.from_values(f.dtype, vals)
    cols = [new_cols[f.name] if f.name in new_cols else batch.column(f.name) for f in out_schema]
    return RecordBatch(out_schema, cols)


# ---------------------------------------------------------------------------
# per-node streaming evaluators
# ---------------------------------------------------------------------------
def _eval_filter(node: Node, ins: list) -> StreamingDataFrame:
    (src,) = ins
    pred: Expr = node.params["predicate"]

    def gen() -> Iterator[RecordBatch]:
        for b in src.iter_batches():
            out = filter_morsel(b, pred)
            if out is not None:
                yield out

    return StreamingDataFrame(src.schema, gen)


def _eval_select(node: Node, ins: list) -> StreamingDataFrame:
    (src,) = ins
    cols = list(node.params["columns"])
    schema = src.schema.select(cols)

    def gen():
        for b in src.iter_batches():
            yield select_morsel(b, cols)

    return StreamingDataFrame(schema, gen)


def project_schema(src_schema: Schema, exprs: dict, keep: bool) -> Schema:
    return _infer_project_schema(src_schema, exprs, keep)


def _infer_project_schema(src_schema: Schema, exprs: dict, keep: bool) -> Schema:
    """Infer projection dtypes by evaluating on an empty batch (cheap, exact)."""
    from repro.core import dtypes as _dt

    empty = RecordBatch.empty(src_schema)
    fields = list(src_schema.fields) if keep else []
    names = {f.name for f in fields}
    for name, e in exprs.items():
        vals = np.asarray(e.evaluate(empty))
        if vals.ndim == 0:  # literal broadcast: dtype of the scalar
            vals = np.asarray([vals[()]])
        try:
            dt = _dt.from_numpy(vals.dtype)
        except KeyError:
            dt = _dt.STRING
        f = Field(name, dt)
        if name in names:
            fields[[x.name for x in fields].index(name)] = f
        else:
            fields.append(f)
            names.add(name)
    return Schema(fields)


def _eval_project(node: Node, ins: list) -> StreamingDataFrame:
    (src,) = ins
    exprs: dict = node.params["exprs"]
    keep: bool = bool(node.params.get("keep", True))

    schema_holder = {"schema": _infer_project_schema(src.schema, exprs, keep)}

    def _projected(b: RecordBatch):
        from repro.core import dtypes as _dt

        cols = []
        for name, e in exprs.items():
            vals = np.asarray(e.evaluate(b))
            if vals.ndim == 0:
                vals = np.full(b.num_rows, vals[()])
            dt = _dt.from_numpy(vals.dtype)
            cols.append((Field(name, dt), Column.from_values(dt, vals)))
        return cols

    def gen():
        for b in src.iter_batches():
            new_cols = _projected(b)
            if keep:
                out = b
                for f, c in new_cols:
                    out = out.with_column(f, c)
            else:
                out = RecordBatch(Schema([f for f, _ in new_cols]), [c for _, c in new_cols])
            schema_holder["schema"] = out.schema
            yield out

    return StreamingDataFrame(schema_holder["schema"], gen)


def _eval_map(node: Node, ins: list) -> StreamingDataFrame:
    (src,) = ins
    mf = get_map(node.params["fn"])
    fn_params = dict(node.params.get("fn_params", {}))
    schema = mf.schema_fn(src.schema, **fn_params)

    def gen():
        for b in src.iter_batches():
            yield map_morsel(b, mf, fn_params)

    return StreamingDataFrame(schema, gen)


def _eval_rebatch(node: Node, ins: list) -> StreamingDataFrame:
    (src,) = ins
    rows = int(node.params["rows"])
    if rows <= 0:
        raise PlanError("rebatch rows must be positive")

    def gen():
        pend: list = []
        pend_rows = 0
        for b in src.iter_batches():
            pend.append(b)
            pend_rows += b.num_rows
            while pend_rows >= rows:
                merged = concat_batches(pend)
                yield merged.slice(0, rows)
                rest = merged.slice(rows, merged.num_rows)
                pend = [rest] if rest.num_rows else []
                pend_rows = rest.num_rows
        if pend_rows:
            yield concat_batches(pend)

    return StreamingDataFrame(src.schema, gen)


def _eval_limit(node: Node, ins: list) -> StreamingDataFrame:
    (src,) = ins
    n = int(node.params["n"])

    def gen():
        seen = 0
        if n <= 0:
            return
        for b in src.iter_batches():
            if seen + b.num_rows >= n:
                yield b.slice(0, n - seen)  # no further upstream pulls
                return
            seen += b.num_rows
            yield b

    return StreamingDataFrame(src.schema, gen)


# ---------------------------------------------------------------------------
# aggregation (group_by().agg() — full / partial / final modes)
# ---------------------------------------------------------------------------
def _sum_dtype(dt):
    return resolve_dtype("int64") if dt.is_integer else resolve_dtype("float64")


def agg_out_fields(in_schema: Schema, keys: list, aggs: dict, mode: str) -> list:
    return _agg_out_fields(in_schema, keys, aggs, mode)


def _agg_out_fields(in_schema: Schema, keys: list, aggs: dict, mode: str) -> list:
    """Output fields for an aggregate node.  ``partial`` emits decomposed
    state (sum+count for mean) so partials union/exchange cleanly and a
    ``final`` stage can combine them."""
    fields = [in_schema.field(k) for k in keys]
    for out, spec in aggs.items():
        fn = spec["fn"]
        column = spec.get("column")
        if fn == "count":
            fields.append(Field(out, resolve_dtype("int64")))
        elif fn == "mean":
            if mode == "partial":
                fields.append(Field(f"{out}__psum", resolve_dtype("float64")))
                fields.append(Field(f"{out}__pcnt", resolve_dtype("int64")))
            else:
                fields.append(Field(out, resolve_dtype("float64")))
        elif fn == "sum":
            src = in_schema.field(_agg_src(out, spec, mode)).dtype
            fields.append(Field(out, _sum_dtype(src)))
        else:  # min / max keep the input dtype
            src = in_schema.field(_agg_src(out, spec, mode)).dtype
            fields.append(Field(out, src))
    return fields


def _agg_src(out: str, spec: dict, mode: str) -> str:
    """Column an agg reads: the user column, or the partial-state column when
    combining (mode=final reads the partial stage's output names)."""
    if mode == "final":
        return out
    return spec.get("column")


class GroupState:
    """Incremental hash-aggregation state across batches (streaming: the
    input is consumed batch-by-batch, never concatenated).

    ``vectorized=True`` (the parallel executor's mode) factorizes fixed-width
    key columns with ``np.unique`` — the python loop shrinks from per-row to
    per-distinct-group-per-batch.  Var-width keys keep the reference row loop
    so first-seen group order is preserved for string keys either way.

    Partial states combine with ``merge`` — the morsel driver builds one
    state per morsel and merges them in morsel order, so the grouped output
    is deterministic regardless of worker count.

    ``backend`` (a ``ComputeBackend``) lets the per-batch fold dispatch to
    the backend's ``segment_reduce`` kernel once the keys are factorized:
    eligible aggregates (counts, integer sums, int32/finite-f32 min/max)
    fold on the accelerator, the rest scatter with numpy — bit-identical
    either way, so a ``None`` backend is the reference semantics.
    """

    def __init__(
        self,
        keys: list,
        aggs: dict,
        mode: str,
        in_schema: Schema,
        vectorized: bool = False,
        backend=None,
    ):
        self.keys = keys
        self.aggs = aggs
        self.mode = mode
        self.in_schema = in_schema
        self.backend = backend
        self.vectorized = vectorized and all(not in_schema.field(k).dtype.is_varwidth for k in keys)
        self.gids: dict = {}  # key tuple -> group id
        self.key_rows: list = []  # representative key values per group
        # state name -> numpy accumulator (grown as groups appear)
        self.acc: dict = {name: np.zeros(0, dt) for name, (_, dt) in self._state_specs().items()}

    def _state_specs(self) -> dict:
        """state name -> (init value, accumulator numpy dtype).

        Integer sum/min/max accumulate in int64 (exact — float64 would
        silently corrupt values past 2^53); floats accumulate in float64.
        """
        specs = {}
        for out, spec in self.aggs.items():
            fn = spec["fn"]
            if fn == "mean":
                specs[f"{out}__psum"] = (0.0, np.float64)
                specs[f"{out}__pcnt"] = (0, np.int64)
            elif fn == "count":
                specs[out] = (0, np.int64)
            else:
                src_dt = self.in_schema.field(_agg_src(out, spec, self.mode)).dtype
                if src_dt.is_integer:
                    if fn in ("min", "max") and src_dt.name == "uint64":
                        # int64 accumulation would wrap values past 2^63 and
                        # compare them under signed order — min over
                        # [1, 2^63+5] must be 1, not the wrapped negative
                        init = {"min": np.iinfo(np.uint64).max, "max": 0}[fn]
                        specs[out] = (init, np.uint64)
                    else:
                        init = {"sum": 0, "min": np.iinfo(np.int64).max, "max": np.iinfo(np.int64).min}[fn]
                        specs[out] = (init, np.int64)
                else:
                    init = {"sum": 0.0, "min": np.inf, "max": -np.inf}[fn]
                    specs[out] = (init, np.float64)
        return specs

    def _intern_groups(self, key_tuples) -> np.ndarray:
        """Map key tuples to (new or existing) group ids."""
        out = np.empty(len(key_tuples), dtype=np.int64)
        gids = self.gids
        for i, kt in enumerate(key_tuples):
            g = gids.get(kt)
            if g is None:
                g = len(gids)
                gids[kt] = g
                self.key_rows.append(kt)
            out[i] = g
        return out

    def _factorize_dense(self, a: np.ndarray):
        """Sort-free factorization for a single integer key over a small
        value range: one scatter builds a first-occurrence LUT instead of
        ``np.unique``'s full-array argsort (the hot path of the aggregate
        fold).  Returns per-row group ids, or None when ineligible."""
        if a.dtype.kind not in "iu" or len(a) == 0:
            return None
        mn, mx = int(a.min()), int(a.max())
        span = mx - mn + 1
        if span > max(1024, 4 * len(a)):
            return None  # LUT would dwarf the batch; np.unique wins
        if a.dtype.kind == "u":
            # native unsigned subtract is exact (every value >= mn) and keeps
            # uint64 keys above 2^63 out of lossy int64 territory
            off = (a - mn).astype(np.int64) if mn else a.astype(np.int64)
        else:
            # widen BEFORE subtracting: narrow signed dtypes (int8 keys
            # spanning -100..100) would wrap in native arithmetic
            off = a.astype(np.int64) - mn
        first = np.full(span, -1, np.int64)
        first[off[::-1]] = np.arange(len(a) - 1, -1, -1, dtype=np.int64)
        vals_off = np.flatnonzero(first >= 0)
        order = np.argsort(first[vals_off], kind="stable")  # first-seen rank
        rank = np.empty(len(order), np.int64)
        rank[order] = np.arange(len(order))
        lut = np.empty(span, np.int64)
        lut[vals_off] = rank
        uniq_keys = [(int(v) + mn,) for v in vals_off[order].tolist()]
        return self._intern_groups(uniq_keys)[lut[off]]

    def _factorize(self, batch: RecordBatch) -> np.ndarray:
        """Per-row group ids for one batch.  The vectorized path matches the
        reference row loop exactly: new groups intern in first-seen row
        order, and any validity mask on a key column falls back to the row
        loop (null keys must stay distinct from the sentinel value)."""
        key_cols = [batch.column(k) for k in self.keys]
        if self.vectorized and all(c.validity is None for c in key_cols):
            arrs = [np.ascontiguousarray(c.values) for c in key_cols]
            if len(arrs) == 1:
                dense = self._factorize_dense(arrs[0])
                if dense is not None:
                    return dense
                uniq, first_idx, inv = np.unique(arrs[0], return_index=True, return_inverse=True)
            else:
                comb = np.empty(batch.num_rows, dtype=[(f"k{i}", a.dtype) for i, a in enumerate(arrs)])
                for i, a in enumerate(arrs):
                    comb[f"k{i}"] = a
                uniq, first_idx, inv = np.unique(comb, return_index=True, return_inverse=True)
            # np.unique sorts; re-rank uniques by first occurrence so group
            # ids come out in first-seen row order (reference parity)
            order = np.argsort(first_idx, kind="stable")
            rank = np.empty(len(order), np.int64)
            rank[order] = np.arange(len(order))
            uniq = uniq[order]
            uniq_keys = [(v,) for v in uniq.tolist()] if len(arrs) == 1 else [tuple(v) for v in uniq.tolist()]
            return self._intern_groups(uniq_keys)[rank[inv.reshape(-1)]]
        # reference path: factorize the key tuple per row
        key_lists = [c.to_pylist() for c in key_cols]
        return self._intern_groups(list(zip(*key_lists)))

    def _grow(self) -> None:
        """Grow every accumulator to the current group count in one shot."""
        ngroups = len(self.gids)
        for name, (init, dt) in self._state_specs().items():
            cur = self.acc[name]
            if len(cur) < ngroups:
                self.acc[name] = np.concatenate([cur, np.full(ngroups - len(cur), init, dt)])

    def _kernel_specs(self, batch: RecordBatch, fresh: bool = False) -> list:
        """(state name, fn, values) triples for ``backend.segment_reduce``.
        The backend accelerates the subset it can reproduce bit-exactly and
        ``update`` scatters the remainder with numpy.

        Float sums (and mean partial sums) are tagged ``fsum`` when the
        state is ``fresh`` (no groups yet — the executor's per-morsel fold):
        starting from +0.0 accumulators, a backend may fold them in its
        f64-accumulating reference path bit-identically.  A reused state
        keeps the plain ``sum`` tag (sequential ``np.add.at`` into non-zero
        accumulators has no order-free equivalent), which backends ignore.
        """
        specs = []
        for out, spec in self.aggs.items():
            fn = spec["fn"]
            if fn == "count":
                if self.mode == "final":
                    specs.append((out, "sum", np.asarray(batch.column(out).values)))
                else:
                    specs.append((out, "count", None))
            elif fn == "mean":
                # psum folds in float64 — fresh states expose it as an
                # ``fsum``; pcnt is a plain count (final mode: a sum of the
                # partial counts)
                if fresh:
                    psrc = f"{out}__psum" if self.mode == "final" else spec["column"]
                    specs.append((f"{out}__psum", "fsum", np.asarray(batch.column(psrc).to_numpy(), np.float64)))
                if self.mode == "final":
                    specs.append((f"{out}__pcnt", "sum", np.asarray(batch.column(f"{out}__pcnt").values)))
                else:
                    specs.append((f"{out}__pcnt", "count", None))
            else:
                vals = np.asarray(batch.column(_agg_src(out, spec, self.mode)).to_numpy())
                if fn == "sum" and fresh and vals.dtype.kind == "f":
                    specs.append((out, "fsum", np.asarray(vals, np.float64)))
                else:
                    specs.append((out, fn, vals))
        return specs

    def update(self, batch: RecordBatch) -> None:
        n = batch.num_rows
        if n == 0:
            return
        fresh = not self.gids
        gidx = self._factorize(batch)
        self._grow()
        ngroups = len(self.gids)
        kres: dict = {}
        if self.backend is not None:
            kres = self.backend.segment_reduce(gidx, ngroups, self._kernel_specs(batch, fresh), n) or {}
        counts = None

        def _counts():
            nonlocal counts
            if counts is None:
                counts = np.bincount(gidx, minlength=ngroups)
            return counts

        # scatter each batch's values straight into the (dtype-exact)
        # accumulators; kernel-folded states combine vectorized instead
        for out, spec in self.aggs.items():
            fn = spec["fn"]
            if fn == "count":
                if out in kres:
                    self.acc[out][:ngroups] += kres[out]
                elif self.mode == "final":
                    vals = np.asarray(batch.column(out).values, dtype=np.int64)
                    np.add.at(self.acc[out], gidx, vals)
                else:
                    self.acc[out] += _counts()
            elif fn == "mean":
                pc, ps = f"{out}__pcnt", f"{out}__psum"
                if self.mode == "final":
                    if ps in kres:
                        self.acc[ps][:ngroups] += kres[ps]
                    else:
                        np.add.at(self.acc[ps], gidx, np.asarray(batch.column(ps).values, np.float64))
                    if pc in kres:
                        self.acc[pc][:ngroups] += kres[pc]
                    else:
                        np.add.at(self.acc[pc], gidx, np.asarray(batch.column(pc).values, np.int64))
                else:
                    if ps in kres:
                        self.acc[ps][:ngroups] += kres[ps]
                    else:
                        vals = np.asarray(batch.column(spec["column"]).to_numpy(), dtype=np.float64)
                        np.add.at(self.acc[ps], gidx, vals)
                    if pc in kres:
                        self.acc[pc][:ngroups] += kres[pc]
                    else:
                        self.acc[pc] += _counts()
            else:  # sum / min / max
                cur = self.acc[out]
                if out in kres:
                    if fn == "sum":
                        cur[:ngroups] += kres[out]
                    else:
                        op = np.minimum if fn == "min" else np.maximum
                        cur[:ngroups] = op(cur[:ngroups], kres[out].astype(cur.dtype))
                else:
                    vals = np.asarray(batch.column(_agg_src(out, spec, self.mode)).to_numpy()).astype(cur.dtype)
                    op = {"sum": np.add, "min": np.minimum, "max": np.maximum}[fn]
                    op.at(cur, gidx, vals)

    def merge(self, other: "GroupState") -> "GroupState":
        """Combine another partial state into this one (same keys/aggs/mode).
        Each of ``other``'s groups maps to a distinct group here, so the
        combine is a plain fancy-indexed binary op per accumulator."""
        self.merge_indexed(other)
        return self

    def merge_indexed(self, other: "GroupState") -> np.ndarray:
        """``merge``, returning the group index of each of ``other``'s groups
        in this state (the spill path maps per-group metadata through it)."""
        m = len(other.key_rows)
        if m == 0:
            return np.zeros(0, np.int64)
        idx = self._intern_groups(other.key_rows)
        self._grow()
        for out, spec in self.aggs.items():
            fn = spec["fn"]
            if fn == "mean":
                for part in (f"{out}__psum", f"{out}__pcnt"):
                    self.acc[part][idx] += other.acc[part][:m]
            else:
                op = {"sum": np.add, "count": np.add, "min": np.minimum, "max": np.maximum}[fn]
                cur = self.acc[out]
                cur[idx] = op(cur[idx], other.acc[out][:m])
        return idx

    def approx_nbytes(self) -> int:
        """Accounted size of this state: accumulator buffers plus an
        estimate of the python-side group directory (dict slot + key tuple
        + interned key values).  Used by the executor's memory budget — an
        estimate is fine, the budget is a spill trigger, not an allocator."""
        acc = sum(a.nbytes for a in self.acc.values())
        per_group = 56  # dict entry + tuple header
        for k in self.keys:
            dt = self.in_schema.field(k).dtype
            per_group += 24 if dt.is_varwidth else dt.width + 8
        return acc + len(self.key_rows) * per_group

    def _key_column(self, f, vals: list) -> Column:
        """Key output column; null keys (masked input rows) materialize as a
        validity-masked column rather than crashing ``from_values``."""
        null = [v is None for v in vals]
        if not any(null):
            return Column.from_values(f.dtype, vals)
        fill = "" if f.dtype.name == "string" else (b"" if f.dtype.name == "binary" else 0)
        c = Column.from_values(f.dtype, [fill if m else v for v, m in zip(vals, null)])
        c.validity = np.asarray([not m for m in null], dtype=bool)
        return c

    def result(self, out_schema: Schema) -> RecordBatch:
        ngroups = len(self.key_rows)
        data = {}
        for i, k in enumerate(self.keys):
            data[k] = [row[i] for row in self.key_rows]
        for out, spec in self.aggs.items():
            fn = spec["fn"]
            if fn == "mean":
                psum = self.acc[f"{out}__psum"]
                pcnt = self.acc[f"{out}__pcnt"]
                if self.mode == "partial":
                    data[f"{out}__psum"] = psum
                    data[f"{out}__pcnt"] = pcnt
                else:
                    data[out] = psum / np.maximum(pcnt, 1)
            else:
                f = out_schema.field(out)
                vals = self.acc[out]
                data[out] = vals.astype(f.dtype.np_dtype) if ngroups else np.zeros(0, f.dtype.np_dtype)
        cols = []
        for f in out_schema:
            vals = data[f.name]
            if f.name in self.keys and not isinstance(vals, np.ndarray):
                cols.append(self._key_column(f, vals))
            else:
                cols.append(Column.from_values(f.dtype, vals if not isinstance(vals, np.ndarray) else np.asarray(vals, f.dtype.np_dtype)))
        return RecordBatch(out_schema, cols)


def _eval_aggregate(node: Node, ins: list) -> StreamingDataFrame:
    (src,) = ins
    keys = list(node.params["keys"])
    aggs = dict(node.params["aggs"])
    mode = node.params.get("mode", "full")
    missing = [k for k in keys if k not in src.schema]
    if missing:
        raise SchemaError(f"aggregate keys missing from input: {missing}")
    out_schema = Schema(_agg_out_fields(src.schema, keys, aggs, mode))

    def gen():
        state = GroupState(keys, aggs, mode, src.schema)
        for b in src.iter_batches():
            state.update(b)
        yield state.result(out_schema)

    return StreamingDataFrame(out_schema, gen)


# back-compat alias for the pre-refactor private name
_GroupState = GroupState


# ---------------------------------------------------------------------------
# join (inner equi-join: right side builds the hash table, left side probes)
# ---------------------------------------------------------------------------
def join_schema(left: Schema, right: Schema, on: list) -> tuple:
    return _join_schema(left, right, on)


def build_join_table(build: RecordBatch, on: list) -> dict:
    """key tuple -> row indices of the (materialized) build side."""
    table: dict = {}
    if build.num_rows:
        for i, kt in enumerate(zip(*[build.column(k).to_pylist() for k in on])):
            table.setdefault(kt, []).append(i)
    return table


def join_probe_indices(batch: RecordBatch, table: dict, on: list) -> tuple:
    """(probe row indices, build row indices) of the matches of one morsel —
    probe-major, build rows in build order within each probe row."""
    probe_keys = list(zip(*[batch.column(k).to_pylist() for k in on]))
    lidx, ridx = [], []
    for i, kt in enumerate(probe_keys):
        for j in table.get(kt, ()):
            lidx.append(i)
            ridx.append(j)
    return np.asarray(lidx, np.int64), np.asarray(ridx, np.int64)


def join_probe_morsel(
    batch: RecordBatch, build: RecordBatch, table: dict, on: list, payload: list, schema: Schema
) -> RecordBatch | None:
    """Probe one morsel against a prebuilt hash table; None when no matches."""
    if batch.num_rows == 0:
        return None
    lidx, ridx = join_probe_indices(batch, table, on)
    if len(lidx) == 0:
        return None
    lpart = batch.take(lidx)
    rpart = build.take(ridx)
    cols = list(lpart.columns)
    for name in payload:
        cols.append(rpart.column(name))
    return RecordBatch(schema, cols)


def _join_schema(left: Schema, right: Schema, on: list) -> tuple:
    """(schema, right_payload_names, rename_map).  Right non-key columns that
    collide with left names get an ``_r`` suffix."""
    for k in on:
        if k not in left or k not in right:
            raise SchemaError(f"join key {k!r} missing from an input")
    fields = list(left.fields)
    left_names = {f.name for f in fields}
    payload, rename = [], {}
    for f in right:
        if f.name in on:
            continue
        name = f.name
        if name in left_names:
            name = f"{f.name}_r"
            if name in left_names:
                raise SchemaError(f"join output column collision on {name!r}")
            rename[f.name] = name
        fields.append(Field(name, f.dtype, f.nullable, f.metadata))
        payload.append(f.name)
    return Schema(fields), payload, rename


def _eval_join(node: Node, ins: list) -> StreamingDataFrame:
    left, right = ins
    on = list(node.params["on"])
    schema, payload, _rename = _join_schema(left.schema, right.schema, on)

    def gen():
        # build: materialize the right side into key -> row indices
        build = right.collect()
        table = build_join_table(build, on)
        # probe: stream the left side, emitting matches per batch
        for b in left.iter_batches():
            out = join_probe_morsel(b, build, table, on, payload, schema)
            if out is not None:
                yield out

    return StreamingDataFrame(schema, gen)


def _eval_union(node: Node, ins: list) -> StreamingDataFrame:
    schema = ins[0].schema
    for s in ins[1:]:
        if not s.schema.equals(schema):
            raise SchemaError("union over mismatched schemas")

    def gen():
        for s in ins:
            yield from s.iter_batches()

    return StreamingDataFrame(schema, gen)


_EVAL = {
    "filter": _eval_filter,
    "select": _eval_select,
    "project": _eval_project,
    "map": _eval_map,
    "rebatch": _eval_rebatch,
    "limit": _eval_limit,
    "union": _eval_union,
    "aggregate": _eval_aggregate,
    "join": _eval_join,
}


def execute_node(node: Node, inputs: list) -> StreamingDataFrame:
    try:
        fn = _EVAL[node.op]
    except KeyError:
        raise PlanError(f"operator {node.op!r} has no local evaluator") from None
    return fn(node, inputs)


def execute(dag: Dag, source_resolver: Callable[[Node], StreamingDataFrame]) -> StreamingDataFrame:
    """Wire the DAG into a lazy pull pipeline and return the output SDF.

    ``source_resolver`` materializes ``source`` / ``exchange`` leaves — the
    server resolves URIs against its catalog; the scheduler resolves exchanges
    against remote pulls.
    """
    materialized: dict = {}
    for nid in dag.topological_order():
        node = dag.nodes[nid]
        if node.op in ("source", "exchange"):
            materialized[nid] = source_resolver(node)
        else:
            materialized[nid] = execute_node(node, [materialized[i] for i in node.inputs])
    return materialized[dag.output]
