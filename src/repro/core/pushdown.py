"""Predicate / projection pushdown (paper §III-B: GET supports predicate
pushdown "circumventing the movement of massive datasets across the network").

Rewrite rules applied to a COOK DAG before scheduling:

  R1 filter∘filter          → filter(p ∧ q)                (merge)
  R2 filter∘select          → select∘filter                (if pred cols ⊆ selected)
  R3 filter∘map             → map∘filter                   (if pred cols ∩ map.writes = ∅)
  R4 filter∘rebatch         → rebatch∘filter               (always legal; filter earlier)
  R5 filter∘union           → union(filter, filter, ...)   (distribute)
  R6 column pruning         → source gains params["columns"] = required set
  R7 filter∘source          → source gains params["predicate"] (scan-level pushdown)
  R8 limit∘select/map/rebatch → pushed below when row-count-preserving
  R9 aggregate(full)∘union  → aggregate(final)∘union(aggregate(partial), ...)
     — distributed partial aggregation: after planning, the partials sit
     in-situ with their sources, so cross-domain exchanges carry partial
     aggregates (≤ one row per group per branch) instead of raw rows
  R10 filter∘aggregate      → aggregate∘filter             (if pred cols ⊆ group keys)
  R11 projection pruning through join/aggregate — required_columns knows
     which input columns a join (keys + consumer needs) and an aggregate
     (keys + agg sources) actually read; sources treat the pruned set as
     advisory (scan keeps the intersection with its real schema)

The rewrites are purely structural (Exprs are data), so the *same* optimizer
runs on the client before COOK submission and on the server before execution.
"""

from __future__ import annotations

from repro.core.dag import Dag, Node
from repro.core.expr import Expr
from repro.core.operators import get_map

__all__ = ["optimize", "required_columns"]

_ROWCOUNT_PRESERVING = {"select", "project", "map", "rebatch"}


def optimize(dag: Dag, max_passes: int = 12) -> Dag:
    dag = dag.copy()
    for _ in range(max_passes):
        changed = False
        changed |= _merge_adjacent_filters(dag)
        changed |= _push_filters_down(dag)
        changed |= _split_aggregate_below_union(dag)
        changed |= _sink_into_sources(dag)
        if not changed:
            break
    _prune_columns(dag)
    dag.validate()
    return dag


# ---------------------------------------------------------------------------
def _single_consumer(dag: Dag, nid: str) -> bool:
    return len(dag.consumers_of(nid)) == 1 and nid != dag.output


def _rewire(dag: Dag, old_top: str, new_top: str) -> None:
    """Point every consumer of old_top at new_top (and the output)."""
    for n in dag.nodes.values():
        n.inputs = [new_top if i == old_top else i for i in n.inputs]
    if dag.output == old_top:
        dag.output = new_top


def _merge_adjacent_filters(dag: Dag) -> bool:
    changed = False
    for n in list(dag.nodes.values()):
        if n.op != "filter" or n.id not in dag.nodes:
            continue
        (child_id,) = n.inputs
        child = dag.nodes[child_id]
        if child.op == "filter" and _single_consumer(dag, child_id):
            n.params["predicate"] = child.params["predicate"] & n.params["predicate"]
            n.inputs = list(child.inputs)
            del dag.nodes[child_id]
            changed = True
    return changed


def _push_filters_down(dag: Dag) -> bool:
    changed = False
    for n in list(dag.nodes.values()):
        if n.id not in dag.nodes or n.op != "filter":
            continue
        (child_id,) = n.inputs
        child = dag.nodes.get(child_id)
        if child is None or not _single_consumer(dag, child_id):
            continue
        pred: Expr = n.params["predicate"]
        cols = pred.referenced_columns()
        swap = False
        if child.op == "select" and cols <= set(child.params["columns"]):
            swap = True
        elif child.op == "project":
            introduced = set(child.params["exprs"].keys())
            if child.params.get("keep", True) and not (cols & introduced):
                swap = True
        elif child.op == "map":
            mf = get_map(child.params["fn"])
            if not (cols & set(mf.writes)):
                swap = True
        elif child.op == "rebatch":
            swap = True
        elif child.op == "aggregate":
            # R10: a filter on the group keys commutes with the aggregation
            if cols <= set(child.params["keys"]):
                swap = True
        elif child.op == "union":
            # distribute: union(filter(a), filter(b), ...)
            new_ids = []
            for i, inp in enumerate(child.inputs):
                fid = f"{n.id}_u{i}"
                dag.nodes[fid] = Node(fid, "filter", {"predicate": pred}, [inp])
                new_ids.append(fid)
            child.inputs = new_ids
            _rewire(dag, n.id, child.id)
            del dag.nodes[n.id]
            changed = True
            continue
        if swap:
            # filter(child(x)) -> child(filter(x))
            grand = list(child.inputs)
            n.inputs = grand
            child.inputs = [n.id]
            _rewire(dag, n.id, child.id)
            # undo the self-loop introduced by rewire on child
            child.inputs = [n.id]
            changed = True
    return changed


def _split_aggregate_below_union(dag: Dag) -> bool:
    """R9: distributed partial aggregation.

    ``aggregate(full)`` directly above a ``union`` splits into per-branch
    ``partial`` aggregates combined by one ``final`` aggregate above the
    union.  The planner then places each partial in-situ with its branch's
    sources, so a cross-domain exchange ships at most one row per group per
    branch instead of the branch's raw rows.
    """
    changed = False
    for n in list(dag.nodes.values()):
        if n.id not in dag.nodes or n.op != "aggregate" or n.params.get("mode", "full") != "full":
            continue
        (child_id,) = n.inputs
        child = dag.nodes.get(child_id)
        if child is None or child.op != "union" or not _single_consumer(dag, child_id):
            continue
        if child.params.get("partition"):
            # a partition-parallel reassembly union: its branches are
            # disjoint part ranges of ONE scan, ordered so the merged stream
            # is byte-identical to the unsplit plan.  Splitting the
            # aggregate here would change the float fold order vs the
            # single-flow plan, breaking that guarantee for zero shipping
            # benefit (the branches are same-domain exchanges).
            continue
        keys = list(n.params["keys"])
        aggs = n.params["aggs"]
        new_inputs = []
        for i, inp in enumerate(child.inputs):
            pid = f"{n.id}_p{i}"
            dag.nodes[pid] = Node(
                pid,
                "aggregate",
                {"keys": list(keys), "aggs": {k: dict(v) for k, v in aggs.items()}, "mode": "partial"},
                [inp],
            )
            new_inputs.append(pid)
        child.inputs = new_inputs
        n.params["mode"] = "final"
        changed = True
    return changed


def _sink_into_sources(dag: Dag) -> bool:
    """R7: a filter directly above a source becomes the source's scan predicate."""
    changed = False
    for n in list(dag.nodes.values()):
        if n.id not in dag.nodes or n.op != "filter":
            continue
        (child_id,) = n.inputs
        child = dag.nodes.get(child_id)
        if child is None or child.op != "source" or not _single_consumer(dag, child_id):
            continue
        pred = n.params["predicate"]
        if "predicate" in child.params:
            child.params["predicate"] = child.params["predicate"] & pred
        else:
            child.params["predicate"] = pred
        _rewire(dag, n.id, child_id)
        del dag.nodes[n.id]
        changed = True
    return changed


# ---------------------------------------------------------------------------
def required_columns(dag: Dag) -> dict:
    """Map node-id -> set of columns required from that node's *output*.

    ``None`` means "all columns" (semantics-opaque consumer).
    """
    req: dict = {nid: set() for nid in dag.nodes}
    opaque: dict = {nid: False for nid in dag.nodes}
    order = dag.topological_order()
    # output consumer needs everything the output produces
    opaque[dag.output] = True
    for nid in reversed(order):
        n = dag.nodes[nid]
        need_all = opaque[nid]
        need = req[nid]
        for inp in n.inputs:
            if n.op == "select":
                for c in n.params["columns"]:
                    req[inp].add(c)
            elif n.op == "filter":
                req[inp] |= n.params["predicate"].referenced_columns()
                req[inp] |= need
                if need_all:
                    opaque[inp] = True
            elif n.op == "project":
                introduced = set(n.params["exprs"].keys())
                for e in n.params["exprs"].values():
                    req[inp] |= e.referenced_columns()
                if n.params.get("keep", True):
                    req[inp] |= need - introduced  # introduced cols don't exist below
                    if need_all:
                        opaque[inp] = True
            elif n.op == "map":
                mf = get_map(n.params["fn"])
                if "*" in mf.reads:
                    opaque[inp] = True
                else:
                    req[inp] |= set(mf.reads)
                    req[inp] |= need - set(mf.writes)
                    if need_all:
                        opaque[inp] = True
            elif n.op == "aggregate":
                # R11: an aggregate reads exactly its keys + agg sources —
                # consumer needs above it never reach the input
                req[inp] |= set(n.params["keys"])
                mode = n.params.get("mode", "full")
                for out, spec in n.params["aggs"].items():
                    if mode == "final":
                        if spec["fn"] == "mean":
                            req[inp] |= {f"{out}__psum", f"{out}__pcnt"}
                        else:
                            req[inp].add(out)
                    elif spec.get("column") is not None:
                        req[inp].add(spec["column"])
            elif n.op == "join":
                # R11: each side needs the join keys plus whatever the
                # consumer needs; the pruned set is advisory at the scan, so
                # naming a column that lives on the other side is harmless.
                req[inp] |= set(n.params["on"])
                req[inp] |= need
                # right-side collisions surface as "<name>_r": map them back
                req[inp] |= {c[:-2] for c in need if c.endswith("_r")}
                if need_all:
                    opaque[inp] = True
            else:  # rebatch/limit/union: passthrough
                req[inp] |= need
                if need_all:
                    opaque[inp] = True
    return {nid: (None if opaque[nid] else req[nid]) for nid in dag.nodes}


def _prune_columns(dag: Dag) -> None:
    """R6: record the required column set on each source for scan pruning."""
    req = required_columns(dag)
    for n in dag.nodes.values():
        if n.op in ("source", "exchange"):
            need = req[n.id]
            if need is not None:
                have = n.params.get("predicate")
                cols = set(need)
                if have is not None:
                    cols |= have.referenced_columns()
                n.params["columns"] = sorted(cols)
