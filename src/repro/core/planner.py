"""Cross-domain task planning (paper §III-D).

A logical COOK DAG references sources in several data centers ("domains" =
``host:port`` authorities).  The planner decomposes it into **physical
sub-tasks** such that every operator executes *in-situ* in the domain that
owns its upstream data ("move operators, not data").  Edges that cross a
domain boundary become **exchange** leaves: the downstream fragment pulls the
upstream fragment's result stream with a scheduler-minted flow token.

Assignment rule (greedy in-situ): a node inherits its inputs' domain while
they agree; the first node whose inputs span domains (e.g. a cross-center
``union``) — and anything above it — runs at the *consumer* domain.  This is
exactly the paper's Fig. 3 decomposition.

Exception (v2): a ``join`` whose inputs span domains runs at its **left
(probe) input's domain** rather than the consumer's — only the build side
crosses the network, and an aggregate above the join stays in-situ with the
probe data.  Callers put the larger input on the left.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

from repro.core import uri as urimod
from repro.core.dag import Dag, Node
from repro.core.errors import PlanError

__all__ = ["SubTask", "Plan", "plan", "assign_domains", "CLIENT_DOMAIN"]

CLIENT_DOMAIN = "client"


@dataclass
class SubTask:
    id: str
    domain: str  # "host:port" authority, or CLIENT_DOMAIN
    dag: Dag
    depends_on: list = field(default_factory=list)  # upstream subtask ids

    @property
    def result_resource(self) -> str:
        """Catalog path under which this sub-task's stream is published."""
        return f"/.flow/{self.id}"

    def result_uri(self) -> str:
        host, _, port = self.domain.partition(":")
        return f"dacp://{host}:{port or urimod.DEFAULT_PORT}{self.result_resource}"

    def to_json(self) -> dict:
        return {
            "id": self.id,
            "domain": self.domain,
            "dag": self.dag.to_json(),
            "depends_on": list(self.depends_on),
        }

    @staticmethod
    def from_json(d: dict) -> "SubTask":
        return SubTask(d["id"], d["domain"], Dag.from_json(d["dag"]), list(d.get("depends_on", [])))


@dataclass
class Plan:
    subtasks: list  # dependency order (upstream first); last one is the root
    root_id: str

    @property
    def root(self) -> SubTask:
        return next(s for s in self.subtasks if s.id == self.root_id)

    def by_id(self, sid: str) -> SubTask:
        return next(s for s in self.subtasks if s.id == sid)

    def to_json(self) -> dict:
        return {"root": self.root_id, "subtasks": [s.to_json() for s in self.subtasks]}

    def to_bytes(self) -> bytes:
        return json.dumps(self.to_json(), separators=(",", ":")).encode()

    @staticmethod
    def from_json(d: dict) -> "Plan":
        return Plan([SubTask.from_json(s) for s in d["subtasks"]], d["root"])


def assign_domains(dag: Dag, client_domain: str = CLIENT_DOMAIN) -> dict:
    domains: dict = {}
    for nid in dag.topological_order():
        n = dag.nodes[nid]
        if n.op in ("source", "exchange"):
            domains[nid] = urimod.parse(n.params["uri"]).authority
        else:
            ins = {domains[i] for i in n.inputs}
            if len(ins) == 1:
                domains[nid] = ins.pop()
            elif n.op == "join":
                # cross-domain join: probe in-situ, ship only the build side
                domains[nid] = domains[n.inputs[0]]
            else:
                domains[nid] = client_domain
    return domains


def plan(dag: Dag, client_domain: str = CLIENT_DOMAIN) -> Plan:
    dag.validate()
    domains = assign_domains(dag, client_domain)
    subtasks: dict = {}
    order: list = []

    def ensure_subtask(producer_id: str) -> SubTask:
        sid = f"st_{producer_id}"
        if sid in subtasks:
            return subtasks[sid]
        frag_nodes, deps = _fragment(producer_id)
        st = SubTask(id=sid, domain=domains[producer_id], dag=Dag(frag_nodes, producer_id), depends_on=deps)
        subtasks[sid] = st
        order.append(st)
        return st

    def _fragment(root_id: str):
        dom = domains[root_id]
        nodes: dict = {}
        deps: list = []

        def walk(nid: str) -> None:
            if nid in nodes:
                return
            node = dag.nodes[nid]
            new_inputs = []
            for i in node.inputs:
                if domains[i] == dom:
                    walk(i)
                    new_inputs.append(i)
                else:
                    up = ensure_subtask(i)  # recurses; upstream registered first
                    if up.id not in deps:
                        deps.append(up.id)
                    ex_id = f"ex__{up.id}__{nid}"
                    nodes[ex_id] = Node(
                        ex_id,
                        "exchange",
                        {"uri": up.result_uri(), "producer": up.id, "token": None},
                        [],
                    )
                    new_inputs.append(ex_id)
            nodes[nid] = Node(node.id, node.op, dict(node.params), new_inputs)

        walk(root_id)
        return nodes, deps

    root = ensure_subtask(dag.output)
    if not order or order[-1].id != root.id:
        raise PlanError("planner produced inconsistent subtask order")
    return Plan(subtasks=order, root_id=root.id)
