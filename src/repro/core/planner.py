"""Cross-domain task planning (paper §III-D).

A logical COOK DAG references sources in several data centers ("domains" =
``host:port`` authorities).  The planner decomposes it into **physical
sub-tasks** such that every operator executes *in-situ* in the domain that
owns its upstream data ("move operators, not data").  Edges that cross a
domain boundary become **exchange** leaves: the downstream fragment pulls the
upstream fragment's result stream with a scheduler-minted flow token.

Assignment rule (greedy in-situ): a node inherits its inputs' domain while
they agree; the first node whose inputs span domains (e.g. a cross-center
``union``) — and anything above it — runs at the *consumer* domain.  This is
exactly the paper's Fig. 3 decomposition.

Exception (v2): a ``join`` whose inputs span domains runs at its **left
(probe) input's domain** rather than the consumer's — only the build side
crosses the network, and an aggregate above the join stays in-situ with the
probe data.  Callers put the larger input on the left.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

from repro.core import uri as urimod
from repro.core.dag import Dag, Node
from repro.core.errors import PlanError

__all__ = ["SubTask", "Plan", "plan", "assign_domains", "partition_plan", "CLIENT_DOMAIN"]

CLIENT_DOMAIN = "client"


@dataclass
class SubTask:
    id: str
    domain: str  # "host:port" authority, or CLIENT_DOMAIN
    dag: Dag
    depends_on: list = field(default_factory=list)  # upstream subtask ids

    @property
    def result_resource(self) -> str:
        """Catalog path under which this sub-task's stream is published."""
        return f"/.flow/{self.id}"

    def result_uri(self) -> str:
        host, _, port = self.domain.partition(":")
        return f"dacp://{host}:{port or urimod.DEFAULT_PORT}{self.result_resource}"

    def to_json(self) -> dict:
        return {
            "id": self.id,
            "domain": self.domain,
            "dag": self.dag.to_json(),
            "depends_on": list(self.depends_on),
        }

    @staticmethod
    def from_json(d: dict) -> "SubTask":
        return SubTask(d["id"], d["domain"], Dag.from_json(d["dag"]), list(d.get("depends_on", [])))


@dataclass
class Plan:
    subtasks: list  # dependency order (upstream first); last one is the root
    root_id: str

    @property
    def root(self) -> SubTask:
        return next(s for s in self.subtasks if s.id == self.root_id)

    def by_id(self, sid: str) -> SubTask:
        return next(s for s in self.subtasks if s.id == sid)

    def to_json(self) -> dict:
        return {"root": self.root_id, "subtasks": [s.to_json() for s in self.subtasks]}

    def to_bytes(self) -> bytes:
        return json.dumps(self.to_json(), separators=(",", ":")).encode()

    @staticmethod
    def from_json(d: dict) -> "Plan":
        return Plan([SubTask.from_json(s) for s in d["subtasks"]], d["root"])


def assign_domains(dag: Dag, client_domain: str = CLIENT_DOMAIN, placement=None) -> dict:
    """Node id -> domain, by the greedy in-situ rule.

    ``placement`` is the mesh's load/replica-aware hook: for a merge node
    whose inputs span domains (the spot the greedy rule would hand to the
    consumer), ``placement(candidates)`` may pick any candidate domain —
    the input domains plus the consumer — using what the mesh knows (bytes
    hosted, heartbeat queue depth).  Returning ``None``, or a domain not in
    the candidate list, falls back to the client-named consumer domain, so
    a mesh with no stats degrades to the paper's Fig. 3 behavior exactly.
    """
    domains: dict = {}
    for nid in dag.topological_order():
        n = dag.nodes[nid]
        if n.op in ("source", "exchange"):
            domains[nid] = urimod.parse(n.params["uri"]).authority
        else:
            ins = {domains[i] for i in n.inputs}
            if len(ins) == 1:
                domains[nid] = ins.pop()
            elif n.op == "join":
                # cross-domain join: probe in-situ, ship only the build side
                domains[nid] = domains[n.inputs[0]]
            else:
                chosen = None
                if placement is not None:
                    candidates = sorted(ins | {client_domain})
                    chosen = placement(candidates)
                    if chosen not in candidates:
                        chosen = None  # stale/garbage hint: keep the default
                domains[nid] = chosen if chosen is not None else client_domain
    return domains


def plan(dag: Dag, client_domain: str = CLIENT_DOMAIN, placement=None) -> Plan:
    dag.validate()
    domains = assign_domains(dag, client_domain, placement=placement)
    subtasks: dict = {}
    order: list = []

    def ensure_subtask(producer_id: str) -> SubTask:
        sid = f"st_{producer_id}"
        if sid in subtasks:
            return subtasks[sid]
        frag_nodes, deps = _fragment(producer_id)
        st = SubTask(id=sid, domain=domains[producer_id], dag=Dag(frag_nodes, producer_id), depends_on=deps)
        subtasks[sid] = st
        order.append(st)
        return st

    def _fragment(root_id: str):
        dom = domains[root_id]
        nodes: dict = {}
        deps: list = []

        def walk(nid: str) -> None:
            if nid in nodes:
                return
            node = dag.nodes[nid]
            new_inputs = []
            for i in node.inputs:
                if domains[i] == dom:
                    walk(i)
                    new_inputs.append(i)
                else:
                    up = ensure_subtask(i)  # recurses; upstream registered first
                    if up.id not in deps:
                        deps.append(up.id)
                    ex_id = f"ex__{up.id}__{nid}"
                    nodes[ex_id] = Node(
                        ex_id,
                        "exchange",
                        {"uri": up.result_uri(), "producer": up.id, "token": None},
                        [],
                    )
                    new_inputs.append(ex_id)
            nodes[nid] = Node(node.id, node.op, dict(node.params), new_inputs)

        walk(root_id)
        return nodes, deps

    root = ensure_subtask(dag.output)
    if not order or order[-1].id != root.id:
        raise PlanError("planner produced inconsistent subtask order")
    return Plan(subtasks=order, root_id=root.id)


# ---------------------------------------------------------------------------
# Partition-parallel SUBMIT (mesh tentpole): split one domain's columnar scan
# into K child flows over disjoint part ranges.
# ---------------------------------------------------------------------------
MAX_PARTITIONS = 64  # union arity cap (core.dag.OPS)


def partition_plan(plan: Plan, part_count_fn, k: int) -> Plan:
    """Split eligible sub-task scans into up to ``k`` partition-parallel
    child sub-tasks over disjoint, contiguous part ranges.

    ``part_count_fn(uri) -> int | None`` answers "how many part files does
    this columnar dataset have" from catalog metadata (local walk or a
    federated DESCRIBE) — ``None`` marks the source ineligible (not
    columnar, unknown dataset, unreachable domain).

    Eligibility is deliberately narrow: a sub-task with exactly ONE source
    node, over a columnar dataset with >= 2 parts, not already split.  The
    child dags replicate that source node *exactly* (including any
    optimizer-pushed ``columns``/``predicate``) plus a ``part_range``; the
    parent's source is replaced by an ordered ``union`` of exchange leaves
    marked ``partition: True`` so no rewrite (R9) crosses it.  Because
    columnar batches never span part files and the executor drains union
    branches in strict input order, the merged stream — and everything the
    parent computes from it — is byte-identical to the unsplit plan, while
    the K child flows scan/decode their ranges concurrently.
    """
    if k < 2:
        return plan
    out: list = []
    for st in plan.subtasks:
        out.extend(_partition_subtask(st, part_count_fn, k))
        out.append(st)
    return Plan(subtasks=out, root_id=plan.root_id)


def _partition_subtask(st: SubTask, part_count_fn, k: int) -> list:
    sources = [n for n in st.dag.nodes.values() if n.op == "source"]
    if len(sources) != 1:
        return []
    src = sources[0]
    if "part_range" in src.params:  # already a partition child: never re-split
        return []
    try:
        n_parts = part_count_fn(src.params["uri"])
    except Exception:  # noqa: BLE001 - eligibility probe must never fail a plan
        return []
    if n_parts is None or n_parts < 2:
        return []
    k_eff = min(int(k), int(n_parts), MAX_PARTITIONS)
    if k_eff < 2:
        return []
    children: list = []
    ex_ids: list = []
    for i in range(k_eff):
        lo = i * n_parts // k_eff
        hi = (i + 1) * n_parts // k_eff
        if hi <= lo:
            continue
        cid = f"{st.id}_p{i}"
        cnode = Node(src.id, "source", {**dict(src.params), "part_range": [lo, hi]}, [])
        child = SubTask(id=cid, domain=st.domain, dag=Dag({src.id: cnode}, src.id))
        children.append(child)
        ex_id = f"ex__{cid}"
        st.dag.nodes[ex_id] = Node(
            ex_id,
            "exchange",
            {"uri": child.result_uri(), "producer": cid, "token": None},
            [],
        )
        ex_ids.append(ex_id)
    union_id = f"{src.id}__partition"
    st.dag.nodes[union_id] = Node(union_id, "union", {"partition": True}, ex_ids)
    for n in st.dag.nodes.values():
        if n.id != union_id:
            n.inputs = [union_id if i == src.id else i for i in n.inputs]
    if st.dag.output == src.id:
        st.dag.output = union_id
    del st.dag.nodes[src.id]
    st.dag.validate()
    st.depends_on = list(st.depends_on) + [c.id for c in children]
    return children
