"""Central registry of every ``DACP_*`` environment knob.

Every env-tunable in the tree is declared HERE, once, with its type,
default, and doc string — and read exclusively through the validated
warn-and-fallback accessors below.  Three things hang off the registry:

  * the accessors (``env_int``/``env_bytes``/…): a garbage or
    out-of-range value warns and falls back to the registered default
    instead of raising deep inside engine construction (the PR-3
    env-knob pattern, now in one place);
  * the README "Environment knobs" table is *generated* from it
    (``python -m repro.core.env --markdown``), so docs cannot drift;
  * ``tools/dacpcheck``'s env pass fails CI on any raw
    ``os.environ`` read of a ``DACP_*`` name outside this module, and
    on any registered knob missing from the README table.

Reading an UNREGISTERED name through an accessor raises ``KeyError``
immediately: registration is the API, not a convention.

This module must stay import-light (os/warnings only) and must not
create locks at import time — it is imported by ``core.lockcheck``
before the lock wrappers install.
"""

from __future__ import annotations

import os
import warnings
from dataclasses import dataclass

__all__ = [
    "Knob",
    "REGISTRY",
    "env_int",
    "env_bytes",
    "env_float",
    "env_str",
    "env_bool",
    "env_dir",
    "env_devices",
    "env_weights",
    "env_morsel_rows",
    "knob_default",
    "parse_weights",
    "markdown_table",
    "check_table",
]


@dataclass(frozen=True)
class Knob:
    name: str
    kind: str  # int | bytes | float | str | bool | dir | devices | weights | morsel_rows
    default: object  # value, or zero-arg callable evaluated per read
    doc: str
    minimum: int | None = None  # int knobs: values below warn + fall back

    def default_value(self):
        return self.default() if callable(self.default) else self.default

    def forms(self) -> str:
        """Human-readable accepted-forms note for the generated table."""
        return {
            "int": "integer",
            "bytes": "`262144` / `256KB` / `16m` / `1g`",
            "float": "positive number (seconds)",
            "str": "string",
            "bool": "`1`/`true`/`yes`/`on` (else off)",
            "dir": "existing writable directory",
            "devices": "comma-separated device indices (`0,1`)",
            "weights": "`alice=4,bob=1`",
            "morsel_rows": "positive integer or `auto`",
        }[self.kind]


REGISTRY: dict[str, Knob] = {}


def _register(name: str, kind: str, default, doc: str, minimum: int | None = None) -> str:
    assert name not in REGISTRY, name
    REGISTRY[name] = Knob(name, kind, default, doc, minimum)
    return name


# --- executor / kernels ----------------------------------------------------
_register(
    "DACP_EXECUTOR_WORKERS",
    "int",
    lambda: min(4, os.cpu_count() or 1),
    "Morsel worker threads per pipeline stage (default `min(4, cpus)`; "
    "`1` = sequential in-line, `0` = reference pull chain).",
    minimum=0,
)
_register(
    "DACP_MORSEL_ROWS",
    "morsel_rows",
    65536,
    "Rows per morsel, or `auto` for the adaptive latency-model sizer.",
)
_register(
    "DACP_BACKEND",
    "str",
    "auto",
    "Compute backend: `numpy` | `pallas` | `auto` (pallas only on a real TPU).",
)
_register(
    "DACP_DEVICES",
    "devices",
    None,
    "Jax device indices that fused-pipeline stages round-robin staged "
    "uploads across (default: jax's default device).",
)
_register(
    "DACP_SCAN_WORKERS",
    "int",
    4,
    "Parallel file readers inside datasource scans.",
    minimum=1,
)
# --- format adapters -------------------------------------------------------
_register(
    "DACP_JSONL_SNIFF_LINES",
    "int",
    256,
    "Lines sampled for JSONL schema inference when no sidecar index "
    "exists (fields are unioned and numeric dtypes widened across the "
    "sample).",
    minimum=1,
)
_register(
    "DACP_JSONL_BLOCK_ROWS",
    "int",
    4096,
    "Rows per block in the JSONL sidecar index — the unit of stats-based "
    "block skipping and of `part_range` splits.",
    minimum=16,
)
_register(
    "DACP_JSONL_INDEX",
    "bool",
    True,
    "Build/use the `_<name>.zdx.json` sidecar line-offset + block-stats "
    "index for JSONL scans (off = plain streaming scan).",
)
_register(
    "DACP_SQLITE_PART_ROWS",
    "int",
    1 << 16,
    "Rows per `part_range` split unit for partition-parallel scans of "
    "SQLite/SDIF containers.",
    minimum=1,
)
# --- memory budget / spill -------------------------------------------------
_register(
    "DACP_MEMORY_BUDGET",
    "bytes",
    0,
    "Byte budget for breaker build states before grace-hash spill "
    "(`0` = unbounded).",
)
_register(
    "DACP_SPILL_DIR",
    "dir",
    None,
    "Directory for spill partition files (default: system temp dir).",
)
# --- flow serving ----------------------------------------------------------
_register(
    "DACP_FLOW_BUFFER",
    "bytes",
    32 << 20,
    "Per-flow result-buffer bound; producers block above it until "
    "consumers ack.",
)
_register(
    "DACP_FLOW_TTL",
    "float",
    60.0,
    "Idle seconds before an unattached flow is reaped.",
)
_register(
    "DACP_FLOW_QUOTA_SLOTS",
    "int",
    0,
    "Total concurrent producer slots across all tenants (`0` = unlimited).",
    minimum=0,
)
_register(
    "DACP_FLOW_QUOTA_CONCURRENCY",
    "int",
    0,
    "Per-tenant concurrent producer cap (`0` = unlimited).",
    minimum=0,
)
_register(
    "DACP_FLOW_QUOTA_BYTES",
    "bytes",
    0,
    "Per-tenant unacked buffered-byte quota (`0` = unlimited).",
)
_register(
    "DACP_FLOW_QUOTA_WEIGHTS",
    "weights",
    None,
    "Stride-scheduler weights per tenant; unlisted tenants get weight 1.",
)
# --- plan cache ------------------------------------------------------------
_register(
    "DACP_PLAN_CACHE_BYTES",
    "bytes",
    64 << 20,
    "Retained result bytes for the plan-fingerprint cache (`0` disables).",
)
_register(
    "DACP_PLAN_CACHE_TTL",
    "float",
    600.0,
    "Seconds a committed cache entry may serve before expiry.",
)
# --- federated catalog mesh ------------------------------------------------
_register(
    "DACP_PEERS",
    "str",
    "",
    "Comma-separated peer authorities (`h2:3101,h3:3101`) forming this "
    "server's catalog mesh; empty disables federation.",
)
_register(
    "DACP_MESH_CACHE_TTL",
    "float",
    10.0,
    "Seconds a federated LIST/DESCRIBE answer may be served from the "
    "mesh cache before peers are re-queried.",
)
_register(
    "DACP_MESH_TIMEOUT",
    "float",
    2.0,
    "Per-peer deadline for mesh scatter-gather and heartbeat probes; a "
    "peer that misses it is reported degraded, not waited for.",
)
_register(
    "DACP_MESH_HEARTBEAT",
    "float",
    5.0,
    "Seconds between background heartbeat probes of mesh peers.",
)
_register(
    "DACP_MESH_DOWN_AFTER",
    "int",
    3,
    "Consecutive failed probes before a peer transitions DEGRADED -> DOWN.",
    minimum=1,
)
_register(
    "DACP_PARTITION_PARALLEL",
    "int",
    0,
    "Split an eligible columnar scan into up to K partition-parallel child "
    "flows over disjoint part ranges (`0`/`1` = off); results stay "
    "byte-identical to the single-flow plan.",
    minimum=0,
)
# --- diagnostics -----------------------------------------------------------
_register(
    "DACP_LOCKCHECK",
    "bool",
    False,
    "Wrap `threading` locks to record the observed lock-acquisition "
    "order (see `tools/dacpcheck`).",
)
_register(
    "DACP_LOCKCHECK_OUT",
    "str",
    "dacpcheck-observed.json",
    "Where the lock-order recorder dumps its observed-edges graph "
    "(unioned into the file if it already exists).",
)


def _knob(name: str, kind: str) -> Knob:
    try:
        k = REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"{name} is not a registered DACP env knob; declare it in repro.core.env"
        ) from None
    if k.kind != kind:
        raise KeyError(f"{name} is registered as kind={k.kind!r}, read as {kind!r}")
    return k


def knob_default(name: str):
    """The registered default (evaluated if callable) — for code that needs
    the fallback value itself, e.g. ``DEFAULT_MORSEL_ROWS``."""
    return REGISTRY[name].default_value()


def env_int(name: str) -> int:
    """Validated integer env read: garbage or below-minimum values warn
    and fall back to the registered default instead of raising."""
    k = _knob(name, "int")
    default = k.default_value()
    raw = os.environ.get(name)
    if raw is None or raw == "":
        return default
    try:
        v = int(raw)
    except ValueError:
        warnings.warn(f"{name}={raw!r} is not an integer; using {default}", stacklevel=2)
        return default
    if k.minimum is not None and v < k.minimum:
        warnings.warn(f"{name}={v} is below the minimum {k.minimum}; using {default}", stacklevel=2)
        return default
    return v


_BYTE_SUFFIX = {"k": 1 << 10, "m": 1 << 20, "g": 1 << 30}


def parse_bytes(raw: str) -> int:
    """``262144`` / ``256k`` / ``256KB`` / ``0.5m`` / ``1g`` → bytes.
    Raises ``ValueError`` on garbage or negative values."""
    s = raw.strip().lower()
    if s.endswith("b"):
        s = s[:-1]
    mult = 1
    if s and s[-1] in _BYTE_SUFFIX:
        mult = _BYTE_SUFFIX[s[-1]]
        s = s[:-1]
    v = float(s) if "." in s else int(s)
    if v < 0:
        raise ValueError(f"negative byte size {raw!r}")
    return int(v * mult)


def env_bytes(name: str) -> int:
    """Validated byte-size env read (suffix forms per ``parse_bytes``);
    garbage or negative values warn and fall back."""
    k = _knob(name, "bytes")
    default = k.default_value()
    raw = os.environ.get(name)
    if raw is None or raw.strip() == "":
        return default
    try:
        return parse_bytes(raw)
    except ValueError:
        warnings.warn(f"{name}={raw!r} is not a byte size; using {default}", stacklevel=2)
        return default


def env_float(name: str) -> float:
    """Validated positive-float env read; non-numbers and values <= 0
    warn/fall back to the registered default."""
    k = _knob(name, "float")
    default = k.default_value()
    raw = os.environ.get(name)
    if raw is None or raw.strip() == "":
        return default
    try:
        v = float(raw)
    except ValueError:
        warnings.warn(f"{name}={raw!r} is not a number; using {default}", stacklevel=2)
        return default
    return v if v > 0 else default


def env_str(name: str) -> str:
    k = _knob(name, "str")
    raw = os.environ.get(name)
    return k.default_value() if raw is None or raw == "" else raw


_TRUE = {"1", "true", "yes", "on"}


def env_bool(name: str) -> bool:
    k = _knob(name, "bool")
    raw = os.environ.get(name)
    if raw is None or raw.strip() == "":
        return bool(k.default_value())
    return raw.strip().lower() in _TRUE


def env_dir(name: str) -> str | None:
    """Validated directory env read: a missing or unwritable directory
    warns at config construction and falls back to the default (None =
    the system temp dir) instead of failing mid-flight."""
    _knob(name, "dir")
    raw = os.environ.get(name)
    if not raw:
        return None
    if not os.path.isdir(raw) or not os.access(raw, os.W_OK):
        warnings.warn(
            f"{name}={raw!r} is not a writable directory; using the system temp dir",
            stacklevel=2,
        )
        return None
    return raw


def env_devices(name: str) -> tuple | None:
    """Validated device-list env read: comma-separated non-negative jax
    device indices; garbage warns and falls back to None (default device)."""
    _knob(name, "devices")
    raw = os.environ.get(name)
    if raw is None or raw.strip() == "":
        return None
    try:
        vals = tuple(int(p) for p in raw.split(",") if p.strip() != "")
    except ValueError:
        warnings.warn(
            f"{name}={raw!r} is not a comma-separated list of device indices; ignoring",
            stacklevel=2,
        )
        return None
    if not vals or any(v < 0 for v in vals):
        warnings.warn(f"{name}={raw!r} must list non-negative device indices; ignoring", stacklevel=2)
        return None
    return vals


def parse_weights(raw: str | None, knob: str = "DACP_FLOW_QUOTA_WEIGHTS") -> dict:
    """``"alice=4,bob=1"`` → {"alice": 4.0, "bob": 1.0}; malformed entries
    warn and fall back to weight 1 (the env-knob validation pattern)."""
    out: dict = {}
    if not raw or not raw.strip():
        return out
    for part in raw.split(","):
        part = part.strip()
        if not part:
            continue
        name, eq, val = part.partition("=")
        try:
            if not eq:
                raise ValueError("missing '='")
            w = float(val)
            if w <= 0:
                raise ValueError("weight must be > 0")
        except ValueError as e:
            warnings.warn(
                f"{knob} entry {part!r} is invalid ({e}); using weight 1",
                stacklevel=2,
            )
            continue
        out[name.strip()] = w
    return out


def env_weights(name: str) -> dict:
    _knob(name, "weights")
    return parse_weights(os.environ.get(name), knob=name)


def env_morsel_rows(name: str):
    """``auto`` or a validated positive integer (registered default on
    garbage / non-positive values)."""
    k = _knob(name, "morsel_rows")
    default = k.default_value()
    raw = os.environ.get(name)
    if raw is None or raw == "":
        return default
    if raw.strip().lower() == "auto":
        return "auto"
    try:
        v = int(raw)
    except ValueError:
        warnings.warn(f"{name}={raw!r} is not an integer; using {default}", stacklevel=2)
        return default
    if v < 1:
        warnings.warn(f"{name}={v} is below the minimum 1; using {default}", stacklevel=2)
        return default
    return v


# ---------------------------------------------------------------------------
# README table generation
# ---------------------------------------------------------------------------
def _default_str(k: Knob) -> str:
    if callable(k.default):
        return "`min(4, cpus)`" if k.name == "DACP_EXECUTOR_WORKERS" else "computed"
    d = k.default
    if d is None:
        return "unset"
    if isinstance(d, bool):
        return "`1`" if d else "off"
    if isinstance(d, int) and d >= 1 << 20 and d % (1 << 20) == 0:
        return f"`{d >> 20}MB`"
    return f"`{d}`"


def markdown_table() -> str:
    """The docs "Environment knobs" table, generated from the registry
    (lives between the markers in docs/operations.md)."""
    lines = [
        "| Variable | Default | Accepted forms | Meaning |",
        "|---|---|---|---|",
    ]
    for k in REGISTRY.values():
        doc = k.doc.replace("|", "\\|")
        lines.append(f"| `{k.name}` | {_default_str(k)} | {k.forms()} | {doc} |")
    return "\n".join(lines)


ENV_TABLE_BEGIN = "<!-- env-table:begin -->"
ENV_TABLE_END = "<!-- env-table:end -->"


def check_table(path: str) -> str | None:
    """None when the table between the markers in ``path`` matches the
    registry, else a human-readable reason — the CI docs-staleness gate."""
    try:
        with open(path) as f:
            text = f.read()
    except OSError as e:
        return f"cannot read {path}: {e}"
    lo = text.find(ENV_TABLE_BEGIN)
    hi = text.find(ENV_TABLE_END)
    if lo < 0 or hi < 0 or hi < lo:
        return f"{path} is missing the {ENV_TABLE_BEGIN} / {ENV_TABLE_END} markers"
    if text[lo + len(ENV_TABLE_BEGIN) : hi].strip() != markdown_table().strip():
        return (
            f"the env-knob table in {path} is stale; regenerate it with "
            "`PYTHONPATH=src python -m repro.core.env` and paste between the markers"
        )
    return None


if __name__ == "__main__":
    import sys

    if len(sys.argv) >= 3 and sys.argv[1] == "--check":
        reason = check_table(sys.argv[2])
        if reason is not None:
            print(reason, file=sys.stderr)
            raise SystemExit(1)
        print(f"{sys.argv[2]}: env-knob table matches the registry")
    else:
        print(markdown_table())
