"""Record Batch beta_k — the atomic unit of transport (paper §III-A).

A RecordBatch holds a finite set of rows conforming to a Schema, laid out
**columnar** in memory: every fixed-width column is one contiguous
little-endian numpy buffer; var-width columns (string/binary) are an
``int64`` offsets buffer (n+1) plus a ``uint8`` data buffer — the layout that
makes zero-copy hand-off between the wire and application memory possible
(the paper's Arrow rationale, re-implemented without the Arrow dependency).

Buffer protocol: ``to_buffers()`` emits ``(header_json, [memoryview, ...])``
and ``from_buffers()`` reconstructs a batch without copying (``np.frombuffer``
views into the framed payload).
"""

from __future__ import annotations

import numpy as np

from repro.core import dtypes
from repro.core.dtypes import DType
from repro.core.errors import SchemaError, TypeMismatchError
from repro.core.schema import Field, Schema

__all__ = ["Column", "RecordBatch", "concat_batches"]

_ALIGN = 8


def _pad(n: int) -> int:
    return (-n) % _ALIGN


class Column:
    """One typed column: fixed-width values or (offsets, data) var-width."""

    __slots__ = ("dtype", "values", "offsets", "data", "validity")

    def __init__(self, dtype: DType, values=None, offsets=None, data=None, validity=None):
        self.dtype = dtype
        self.values = values  # fixed-width: np.ndarray
        self.offsets = offsets  # var-width: int64[n+1]
        self.data = data  # var-width: uint8[*]
        self.validity = validity  # optional bool[n]
        if dtype.is_varwidth:
            assert offsets is not None and data is not None
            assert offsets.dtype == np.int64 and data.dtype == np.uint8
        else:
            assert values is not None
            if values.dtype != dtype.np_dtype:
                raise TypeMismatchError(
                    f"column buffer dtype {values.dtype} != declared {dtype.name}"
                )

    # -- construction --------------------------------------------------------
    @staticmethod
    def from_values(dtype: DType, seq) -> "Column":
        dtype = dtypes.resolve(dtype)
        if dtype.is_varwidth:
            blobs = []
            for v in seq:
                if isinstance(v, str):
                    v = v.encode()
                elif isinstance(v, (bytes, bytearray, memoryview, np.ndarray)):
                    v = bytes(v)
                else:
                    raise TypeMismatchError(f"cannot store {type(v).__name__} in {dtype.name}")
                blobs.append(v)
            lens = np.fromiter((len(b) for b in blobs), dtype=np.int64, count=len(blobs))
            offsets = np.zeros(len(blobs) + 1, dtype=np.int64)
            np.cumsum(lens, out=offsets[1:])
            data = np.frombuffer(b"".join(blobs), dtype=np.uint8) if blobs else np.zeros(0, np.uint8)
            return Column(dtype, offsets=offsets, data=data)
        arr = np.asarray(seq, dtype=dtype.np_dtype)
        if arr.ndim != 1:
            arr = arr.reshape(-1)
        return Column(dtype, values=np.ascontiguousarray(arr))

    # -- access ---------------------------------------------------------------
    def __len__(self) -> int:
        if self.dtype.is_varwidth:
            return len(self.offsets) - 1
        return len(self.values)

    @property
    def nbytes(self) -> int:
        if self.dtype.is_varwidth:
            n = self.offsets.nbytes + self.data.nbytes
        else:
            n = self.values.nbytes
        if self.validity is not None:
            n += self.validity.nbytes
        return n

    def value(self, i: int):
        if self.validity is not None and not self.validity[i]:
            return None
        if self.dtype.is_varwidth:
            raw = bytes(self.data[self.offsets[i] : self.offsets[i + 1]])
            return raw.decode() if self.dtype.name == "string" else raw
        v = self.values[i]
        return v.item() if isinstance(v, np.generic) else v

    def to_pylist(self) -> list:
        return [self.value(i) for i in range(len(self))]

    def to_numpy(self) -> np.ndarray:
        if self.dtype.is_varwidth:
            raise TypeMismatchError(f"{self.dtype.name} column is not dense-numeric")
        return self.values

    # -- kernels used by the operator library ---------------------------------
    def take(self, idx: np.ndarray) -> "Column":
        validity = self.validity[idx] if self.validity is not None else None
        if not self.dtype.is_varwidth:
            return Column(self.dtype, values=self.values[idx], validity=validity)
        lens = self.offsets[1:][idx] - self.offsets[:-1][idx]
        new_off = np.zeros(len(idx) + 1, dtype=np.int64)
        np.cumsum(lens, out=new_off[1:])
        out = np.empty(int(new_off[-1]), dtype=np.uint8)
        for j, i in enumerate(idx):
            out[new_off[j] : new_off[j + 1]] = self.data[self.offsets[i] : self.offsets[i + 1]]
        return Column(self.dtype, offsets=new_off, data=out, validity=validity)

    def filter(self, mask: np.ndarray) -> "Column":
        return self.take(np.flatnonzero(mask))

    def slice(self, start: int, stop: int) -> "Column":
        validity = self.validity[start:stop] if self.validity is not None else None
        if not self.dtype.is_varwidth:
            return Column(self.dtype, values=self.values[start:stop], validity=validity)
        off = self.offsets[start : stop + 1]
        data = self.data[off[0] : off[-1]]
        return Column(self.dtype, offsets=off - off[0], data=data, validity=validity)

    # -- buffers ---------------------------------------------------------------
    def buffers(self):
        """Returns (layout_descriptor, [np buffers]) for wire framing."""
        bufs, kinds = [], []
        if self.validity is not None:
            bufs.append(np.ascontiguousarray(self.validity))
            kinds.append("validity")
        if self.dtype.is_varwidth:
            bufs.append(np.ascontiguousarray(self.offsets))
            kinds.append("offsets")
            bufs.append(np.ascontiguousarray(self.data))
            kinds.append("data")
        else:
            bufs.append(np.ascontiguousarray(self.values))
            kinds.append("data")
        return kinds, bufs

    @staticmethod
    def from_buffers(dtype: DType, n_rows: int, kinds, raw_views) -> "Column":
        m = dict(zip(kinds, raw_views))
        validity = None
        if "validity" in m:
            validity = np.frombuffer(m["validity"], dtype=np.bool_, count=n_rows)
        if dtype.is_varwidth:
            offsets = np.frombuffer(m["offsets"], dtype=np.int64, count=n_rows + 1)
            data = np.frombuffer(m["data"], dtype=np.uint8)
            data = data[: int(offsets[-1])]
            return Column(dtype, offsets=offsets, data=data, validity=validity)
        values = np.frombuffer(m["data"], dtype=dtype.np_dtype, count=n_rows)
        return Column(dtype, values=values, validity=validity)


class RecordBatch:
    """schema + columns, all the same length."""

    __slots__ = ("schema", "columns", "num_rows")

    def __init__(self, schema: Schema, columns):
        columns = list(columns)
        if len(columns) != len(schema):
            raise SchemaError(f"{len(columns)} columns for {len(schema)}-field schema")
        n = len(columns[0]) if columns else 0
        for f, c in zip(schema, columns):
            if len(c) != n:
                raise SchemaError(f"ragged batch: column {f.name} has {len(c)} rows != {n}")
            if c.dtype != f.dtype:
                raise TypeMismatchError(f"column {f.name}: {c.dtype.name} != schema {f.dtype.name}")
        self.schema = schema
        self.columns = columns
        self.num_rows = n

    # -- construction ----------------------------------------------------------
    @staticmethod
    def from_pydict(data: dict, schema: Schema | None = None) -> "RecordBatch":
        if schema is None:
            fields = []
            for k, v in data.items():
                arr = np.asarray(v)
                if arr.dtype.kind in ("U", "S", "O"):
                    dt = dtypes.STRING
                    if len(arr) and isinstance(np.asarray(v, dtype=object).reshape(-1)[0], (bytes, bytearray)):
                        dt = dtypes.BINARY
                else:
                    dt = dtypes.from_numpy(arr.dtype)
                fields.append(Field(k, dt))
            schema = Schema(fields)
        cols = [Column.from_values(schema.dtype(k), data[k]) for k in schema.names]
        return RecordBatch(schema, cols)

    @staticmethod
    def empty(schema: Schema) -> "RecordBatch":
        return RecordBatch(schema, [Column.from_values(f.dtype, []) for f in schema])

    # -- access ------------------------------------------------------------------
    def column(self, name: str) -> Column:
        return self.columns[self.schema.index(name)]

    def __len__(self) -> int:
        return self.num_rows

    @property
    def nbytes(self) -> int:
        return sum(c.nbytes for c in self.columns)

    def row(self, i: int) -> dict:
        return {f.name: c.value(i) for f, c in zip(self.schema, self.columns)}

    def iter_rows(self):
        """Iterator<Row> semantics over a columnar physical layout (§III-A)."""
        for i in range(self.num_rows):
            yield self.row(i)

    def to_pydict(self) -> dict:
        return {f.name: c.to_pylist() for f, c in zip(self.schema, self.columns)}

    # -- relational kernels --------------------------------------------------------
    def select(self, names) -> "RecordBatch":
        return RecordBatch(self.schema.select(names), [self.column(n) for n in names])

    def take(self, idx: np.ndarray) -> "RecordBatch":
        idx = np.asarray(idx, dtype=np.int64)
        return RecordBatch(self.schema, [c.take(idx) for c in self.columns])

    def filter(self, mask: np.ndarray) -> "RecordBatch":
        mask = np.asarray(mask, dtype=bool)
        if len(mask) != self.num_rows:
            raise SchemaError(f"mask length {len(mask)} != {self.num_rows}")
        return self.take(np.flatnonzero(mask))

    def slice(self, start: int, stop: int) -> "RecordBatch":
        start = max(0, min(start, self.num_rows))
        stop = max(start, min(stop, self.num_rows))
        return RecordBatch(self.schema, [c.slice(start, stop) for c in self.columns])

    def with_column(self, field: Field, col: Column) -> "RecordBatch":
        if field.name in self.schema:
            i = self.schema.index(field.name)
            fields = list(self.schema.fields)
            fields[i] = field
            cols = list(self.columns)
            cols[i] = col
            return RecordBatch(Schema(fields), cols)
        return RecordBatch(self.schema.append(field), list(self.columns) + [col])

    # -- wire -------------------------------------------------------------------
    def to_buffers(self):
        """(header: dict, buffers: [np.ndarray]) — buffers are NOT copied."""
        header_cols, bufs = [], []
        for f, c in zip(self.schema, self.columns):
            kinds, cb = c.buffers()
            header_cols.append(
                {"name": f.name, "kinds": kinds, "lens": [int(b.nbytes) for b in cb]}
            )
            bufs.extend(cb)
        header = {"num_rows": int(self.num_rows), "columns": header_cols}
        return header, bufs

    @staticmethod
    def from_buffers(schema: Schema, header: dict, payload: memoryview) -> "RecordBatch":
        """Zero-copy reconstruct from a contiguous 8-aligned payload."""
        n = int(header["num_rows"])
        cols = []
        pos = 0
        for f, hc in zip(schema, header["columns"]):
            views = []
            for ln in hc["lens"]:
                views.append(payload[pos : pos + ln])
                pos += ln + _pad(ln)
            cols.append(Column.from_buffers(f.dtype, n, hc["kinds"], views))
        return RecordBatch(schema, cols)

    _PAD = b"\x00" * (_ALIGN - 1)

    @staticmethod
    def payload_parts(bufs) -> list:
        """Buffer parts (with 8-byte alignment padding interleaved) ready for
        a writev-style frame write — **no concatenation copy**.  Views
        reference the column memory directly; the writer streams them out
        sequentially (``FrameWriter.write_frame`` with a list body)."""
        parts = []
        for b in bufs:
            raw = memoryview(b).cast("B")
            parts.append(raw)
            p = _pad(len(raw))
            if p:
                parts.append(RecordBatch._PAD[:p])
        return parts

    @staticmethod
    def payload_bytes(bufs) -> bytes:
        """Concatenate buffers with 8-byte alignment (the frame body)."""
        return b"".join(RecordBatch.payload_parts(bufs))


def concat_batches(batches) -> RecordBatch:
    batches = [b for b in batches if b.num_rows >= 0]
    if not batches:
        raise SchemaError("concat of zero batches")
    schema = batches[0].schema
    for b in batches[1:]:
        if not b.schema.equals(schema):
            raise SchemaError(f"schema mismatch in concat: {b.schema} vs {schema}")
    cols = []
    for i, f in enumerate(schema):
        if f.dtype.is_varwidth:
            offs = [b.columns[i].offsets for b in batches]
            datas = [b.columns[i].data for b in batches]
            lens = np.concatenate([o[1:] - o[:-1] for o in offs]) if offs else np.zeros(0, np.int64)
            new_off = np.zeros(len(lens) + 1, dtype=np.int64)
            np.cumsum(lens, out=new_off[1:])
            data = np.concatenate(datas) if datas else np.zeros(0, np.uint8)
            col = Column(f.dtype, offsets=new_off, data=data)
        else:
            col = Column(f.dtype, values=np.concatenate([b.columns[i].values for b in batches]))
        v = [b.columns[i].validity for b in batches]
        if any(x is not None for x in v):
            col.validity = np.concatenate(
                [x if x is not None else np.ones(b.num_rows, bool) for x, b in zip(v, batches)]
            )
        cols.append(col)
    return RecordBatch(schema, cols)
