"""DACP scientific type system (paper §III-A, eq. 2).

The paper's critique of REST/JSON is that JSON has one ``Number`` type; DACP
schemas must distinguish int8 from uint64 from float16.  We therefore define an
explicit closed set of primitive types, each with a stable wire name, a numpy
dtype for columnar buffers, and a fixed byte width (var-width types use an
offsets+data representation, see ``repro.core.batch``).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["DType", "resolve", "from_numpy", "PRIMITIVES", "BINARY", "STRING"]


@dataclass(frozen=True)
class DType:
    """A DACP primitive type.

    name:      stable wire identifier (``"float32"``, ``"binary"``, ...)
    np_dtype:  numpy dtype used for the column buffer (``object`` is never
               used; var-width types store uint8 data + int64 offsets)
    width:     bytes per value for fixed-width types, ``None`` for var-width
    """

    name: str
    np_name: str
    width: int | None

    @property
    def np_dtype(self) -> np.dtype:
        return np.dtype(self.np_name)

    @property
    def is_varwidth(self) -> bool:
        return self.width is None

    @property
    def is_numeric(self) -> bool:
        return not self.is_varwidth and self.name != "bool"

    @property
    def is_float(self) -> bool:
        return self.name.startswith("float") or self.name == "bfloat16"

    @property
    def is_integer(self) -> bool:
        return self.name.startswith(("int", "uint"))

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"dtype<{self.name}>"


def _fixed(name: str, np_name: str | None = None) -> DType:
    np_name = np_name or name
    return DType(name, np_name, np.dtype(np_name).itemsize)


INT8 = _fixed("int8")
INT16 = _fixed("int16")
INT32 = _fixed("int32")
INT64 = _fixed("int64")
UINT8 = _fixed("uint8")
UINT16 = _fixed("uint16")
UINT32 = _fixed("uint32")
UINT64 = _fixed("uint64")
FLOAT16 = _fixed("float16")
FLOAT32 = _fixed("float32")
FLOAT64 = _fixed("float64")
BOOL = _fixed("bool")
# Variable-width binary blob (the File-List-Framing content column) and utf8.
BINARY = DType("binary", "uint8", None)
STRING = DType("string", "uint8", None)

PRIMITIVES: dict[str, DType] = {
    t.name: t
    for t in (
        INT8,
        INT16,
        INT32,
        INT64,
        UINT8,
        UINT16,
        UINT32,
        UINT64,
        FLOAT16,
        FLOAT32,
        FLOAT64,
        BOOL,
        BINARY,
        STRING,
    )
}


def resolve(name: str | DType) -> DType:
    """Resolve a wire name (or pass through a DType) to a DType."""
    if isinstance(name, DType):
        return name
    try:
        return PRIMITIVES[name]
    except KeyError:
        raise KeyError(f"unknown DACP dtype {name!r}; known: {sorted(PRIMITIVES)}") from None


def from_numpy(dt: np.dtype) -> DType:
    """Map a numpy dtype onto the DACP type system."""
    dt = np.dtype(dt)
    if dt.kind in ("S", "U", "O"):
        return STRING
    name = dt.name
    if name not in PRIMITIVES:
        raise KeyError(f"numpy dtype {dt} has no DACP primitive")
    return PRIMITIVES[name]
