"""Runtime lock-order recorder (``DACP_LOCKCHECK=1``).

Patches ``threading.Lock``/``RLock``/``Condition`` so every lock *created
by repro code* is tracked: each thread keeps a stack of held locks, and
acquiring B while A is held records the edge ``A -> B`` under the same
canonical node names the static analyzer uses (``ClassName.attr`` for
``self.X = threading.Lock()`` sites, ``stem.func.var`` for function
locals, ``stem.var`` at module level).  Two instances of the *same* named
lock held together are recorded separately as a cross-instance hazard.

At process exit the observed graph is dumped to ``DACP_LOCKCHECK_OUT``
(unioned with any existing file, so a multi-process test run
accumulates).  CI feeds the dump to
``python -m tools.dacpcheck --runtime-graph`` which unions it with the
static graph before cycle detection.

Locks created outside repro frames (stdlib ``queue.Queue`` internals,
pytest, logging) pass through untracked, so overhead lands only on the
locks we care about.
"""

from __future__ import annotations

import atexit
import json
import linecache
import os
import re
import sys
import threading

from repro.core.env import env_bool, env_str

_ATTR_RE = re.compile(r"self\.(\w+)\s*[:=]")
_VAR_RE = re.compile(r"(\w+)\s*[:=]")

_state = threading.local()
_edges: set = set()
_cross: set = set()
_graph_lock = threading.Lock()
_installed = False
_orig = {}


def _held():
    st = getattr(_state, "held", None)
    if st is None:
        st = _state.held = []
    return st


def _note_acquire(tracked) -> None:
    held = _held()
    for h in held:
        if h is tracked:
            return  # reentrant re-acquire of the same instance: no new edges
    new_edges = []
    new_cross = []
    for h in held:
        if h.dacp_name == tracked.dacp_name:
            new_cross.append((h.dacp_name, tracked.dacp_name))
        else:
            new_edges.append((h.dacp_name, tracked.dacp_name))
    held.append(tracked)
    if new_edges or new_cross:
        with _graph_lock:
            _edges.update(new_edges)
            _cross.update(new_cross)


def _note_release(tracked) -> None:
    held = _held()
    for i in range(len(held) - 1, -1, -1):
        if held[i] is tracked:
            del held[i]
            return


def _name_from_frame(frame, kind: str) -> str:
    line = linecache.getline(frame.f_code.co_filename, frame.f_lineno)
    self_obj = frame.f_locals.get("self")
    if self_obj is not None:
        m = _ATTR_RE.search(line)
        if m:
            return f"{type(self_obj).__name__}.{m.group(1)}"
    stem = os.path.splitext(os.path.basename(frame.f_code.co_filename))[0]
    m = _VAR_RE.search(line)
    var = m.group(1) if m else f"anon_{kind}"
    if frame.f_code.co_name == "<module>":
        return f"{stem}.{var}"
    return f"{stem}.{frame.f_code.co_name}.{var}"


def _repro_frame(frame) -> bool:
    fn = frame.f_code.co_filename.replace("\\", "/")
    return "/repro/" in fn and "/tools/" not in fn


class _TrackedLock:
    def __init__(self, inner, name: str):
        self._inner = inner
        self.dacp_name = name

    def acquire(self, *args, **kwargs):
        got = self._inner.acquire(*args, **kwargs)
        if got:
            _note_acquire(self)
        return got

    def release(self):
        _note_release(self)
        self._inner.release()

    def locked(self):
        return self._inner.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def __repr__(self):
        return f"<tracked {self.dacp_name} {self._inner!r}>"


class _TrackedCondition:
    def __init__(self, inner, name: str):
        self._inner = inner
        self.dacp_name = name

    def acquire(self, *args, **kwargs):
        got = self._inner.acquire(*args, **kwargs)
        if got:
            _note_acquire(self)
        return got

    def release(self):
        _note_release(self)
        self._inner.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def wait(self, timeout=None):
        # the underlying lock is released for the duration of the wait
        _note_release(self)
        try:
            return self._inner.wait(timeout)
        finally:
            _note_acquire(self)

    def wait_for(self, predicate, timeout=None):
        _note_release(self)
        try:
            return self._inner.wait_for(predicate, timeout)
        finally:
            _note_acquire(self)

    def notify(self, n=1):
        self._inner.notify(n)

    def notify_all(self):
        self._inner.notify_all()

    def __repr__(self):
        return f"<tracked {self.dacp_name} {self._inner!r}>"


def _factory(orig, kind: str):
    def make(*args, **kwargs):
        frame = sys._getframe(1)
        if not _repro_frame(frame):
            return orig(*args, **kwargs)
        name = _name_from_frame(frame, kind)
        if kind == "cond":
            # unwrap a tracked lock handed to Condition(lock): the condition
            # node subsumes it for ordering purposes
            if args and isinstance(args[0], (_TrackedLock,)):
                args = (args[0]._inner,) + args[1:]
            lk = kwargs.get("lock")
            if isinstance(lk, _TrackedLock):
                kwargs["lock"] = lk._inner
            return _TrackedCondition(orig(*args, **kwargs), name)
        return _TrackedLock(orig(*args, **kwargs), name)

    return make


def observed() -> dict:
    with _graph_lock:
        return {
            "edges": sorted([a, b] for a, b in _edges),
            "cross_instance": sorted([a, b] for a, b in _cross),
        }


def dump(path: str | None = None) -> str:
    path = path or env_str("DACP_LOCKCHECK_OUT")
    data = observed()
    try:
        with open(path, encoding="utf-8") as f:
            prior = json.load(f)
        data["edges"] = sorted({tuple(e) for e in prior.get("edges", [])} | {tuple(e) for e in data["edges"]})
        data["cross_instance"] = sorted(
            {tuple(e) for e in prior.get("cross_instance", [])} | {tuple(e) for e in data["cross_instance"]})
        data["edges"] = [list(e) for e in data["edges"]]
        data["cross_instance"] = [list(e) for e in data["cross_instance"]]
    except (OSError, ValueError):
        pass
    tmp = f"{path}.tmp{os.getpid()}"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(data, f, indent=1)
    os.replace(tmp, path)
    return path


def install(out_path: str | None = None) -> bool:
    """Patch the threading factories; returns True if newly installed."""
    global _installed
    if _installed:
        return False
    _orig["Lock"] = threading.Lock
    _orig["RLock"] = threading.RLock
    _orig["Condition"] = threading.Condition
    threading.Lock = _factory(_orig["Lock"], "lock")
    threading.RLock = _factory(_orig["RLock"], "rlock")
    threading.Condition = _factory(_orig["Condition"], "cond")
    _installed = True

    def _dump_at_exit():
        try:
            dump(out_path)
        except OSError:
            pass  # out dir may be gone by interpreter teardown (tmp paths)

    atexit.register(_dump_at_exit)
    return True


def uninstall() -> None:
    global _installed
    if not _installed:
        return
    threading.Lock = _orig["Lock"]
    threading.RLock = _orig["RLock"]
    threading.Condition = _orig["Condition"]
    _installed = False


def install_if_enabled() -> bool:
    if env_bool("DACP_LOCKCHECK"):
        return install()
    return False
