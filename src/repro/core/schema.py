"""Schema S = {(attr_1, tau_1), ..., (attr_m, tau_m)}  (paper §III-A eq. 2).

A Schema is an ordered list of named, typed fields.  It travels ahead of the
frame stream (one schema frame, then batch frames) so the receiver can
interpret every batch without side-channel metadata — the paper's fix for
"data and metadata are fragmented in the access path".
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field as dc_field

from repro.core import dtypes
from repro.core.dtypes import DType
from repro.core.errors import SchemaError

__all__ = ["Field", "Schema"]


@dataclass(frozen=True)
class Field:
    name: str
    dtype: DType
    nullable: bool = False
    metadata: tuple = ()  # tuple of (key, value) pairs; hashable

    def to_json(self) -> dict:
        d = {"name": self.name, "dtype": self.dtype.name, "nullable": self.nullable}
        if self.metadata:
            d["metadata"] = dict(self.metadata)
        return d

    @staticmethod
    def from_json(d: dict) -> "Field":
        return Field(
            name=d["name"],
            dtype=dtypes.resolve(d["dtype"]),
            nullable=bool(d.get("nullable", False)),
            metadata=tuple(sorted((d.get("metadata") or {}).items())),
        )


class Schema:
    """Ordered, uniquely-named, typed field list."""

    __slots__ = ("fields", "_index")

    def __init__(self, fields):
        fields = list(fields)
        norm = []
        for f in fields:
            if isinstance(f, Field):
                norm.append(f)
            elif isinstance(f, tuple) and len(f) >= 2:
                norm.append(Field(f[0], dtypes.resolve(f[1]), *f[2:]))
            else:
                raise SchemaError(f"cannot interpret schema field {f!r}")
        names = [f.name for f in norm]
        if len(set(names)) != len(names):
            dup = sorted({n for n in names if names.count(n) > 1})
            raise SchemaError(f"duplicate column names {dup}")
        self.fields: tuple = tuple(norm)
        self._index = {f.name: i for i, f in enumerate(self.fields)}

    # -- access -------------------------------------------------------------
    @property
    def names(self) -> list:
        return [f.name for f in self.fields]

    def __len__(self) -> int:
        return len(self.fields)

    def __iter__(self):
        return iter(self.fields)

    def __contains__(self, name: str) -> bool:
        return name in self._index

    def field(self, name: str) -> Field:
        try:
            return self.fields[self._index[name]]
        except KeyError:
            raise SchemaError(f"no column {name!r}; have {self.names}") from None

    def index(self, name: str) -> int:
        try:
            return self._index[name]
        except KeyError:
            raise SchemaError(f"no column {name!r}; have {self.names}") from None

    def dtype(self, name: str) -> DType:
        return self.field(name).dtype

    # -- algebra ------------------------------------------------------------
    def select(self, names) -> "Schema":
        return Schema([self.field(n) for n in names])

    def rename(self, mapping: dict) -> "Schema":
        return Schema(
            [
                Field(mapping.get(f.name, f.name), f.dtype, f.nullable, f.metadata)
                for f in self.fields
            ]
        )

    def append(self, f: Field) -> "Schema":
        return Schema(list(self.fields) + [f])

    def equals(self, other: "Schema", check_metadata: bool = False) -> bool:
        if len(self) != len(other):
            return False
        for a, b in zip(self.fields, other.fields):
            if a.name != b.name or a.dtype != b.dtype or a.nullable != b.nullable:
                return False
            if check_metadata and a.metadata != b.metadata:
                return False
        return True

    def __eq__(self, other) -> bool:
        return isinstance(other, Schema) and self.equals(other)

    def __hash__(self) -> int:
        return hash(self.fields)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        cols = ", ".join(f"{f.name}:{f.dtype.name}" for f in self.fields)
        return f"Schema({cols})"

    # -- wire ---------------------------------------------------------------
    def to_json(self) -> list:
        return [f.to_json() for f in self.fields]

    def to_bytes(self) -> bytes:
        return json.dumps(self.to_json(), separators=(",", ":")).encode()

    @staticmethod
    def from_json(items) -> "Schema":
        return Schema([Field.from_json(d) for d in items])

    @staticmethod
    def from_bytes(b: bytes) -> "Schema":
        return Schema.from_json(json.loads(b.decode()))
