"""Short-lived HMAC access tokens (paper §III-C/D).

The interaction model: connect → exchange credentials → receive a short-lived
token → present the token on every GET/PUT/COOK.  During cross-domain
scheduling, downstream nodes must present a *flow token* minted by the
scheduler to pull from upstream nodes; flow tokens are scoped to a single
(resource, verb) pair so a leaked token cannot be replayed elsewhere.

Tokens are `payload_b64.hmac_sha256(secret, payload)` — stateless to verify,
so any replica of a server can validate pulls without shared session state.
"""

from __future__ import annotations

import base64
import hashlib
import hmac
import json
import os
import time

from repro.core.errors import TokenError

__all__ = ["TokenAuthority", "Token"]

_SKEW = 2.0  # seconds of clock skew tolerated


class Token:
    __slots__ = ("raw", "claims")

    def __init__(self, raw: str, claims: dict):
        self.raw = raw
        self.claims = claims

    @property
    def subject(self) -> str:
        return self.claims.get("sub", "")

    def __str__(self) -> str:
        return self.raw


class TokenAuthority:
    """Mints and verifies scoped, expiring tokens."""

    def __init__(self, secret: bytes | None = None, ttl_s: float = 300.0):
        self.secret = secret if secret is not None else os.urandom(32)
        self.ttl_s = float(ttl_s)
        self._revoked: set = set()

    # -- mint ------------------------------------------------------------------
    def mint(
        self,
        subject: str,
        resource: str = "*",
        verbs: tuple = ("GET", "PUT", "COOK"),
        ttl_s: float | None = None,
    ) -> Token:
        now = time.time()
        claims = {
            "sub": subject,
            "res": resource,
            "verbs": sorted(verbs),
            "iat": now,
            "exp": now + (self.ttl_s if ttl_s is None else float(ttl_s)),
            "jti": base64.urlsafe_b64encode(os.urandom(9)).decode(),
        }
        payload = base64.urlsafe_b64encode(
            json.dumps(claims, separators=(",", ":"), sort_keys=True).encode()
        ).decode()
        sig = self._sign(payload)
        return Token(f"{payload}.{sig}", claims)

    def mint_flow_token(self, subtask_id: str, resource: str, ttl_s: float = 120.0) -> Token:
        """Single-purpose pull token for one inter-domain exchange edge."""
        return self.mint(subject=f"flow:{subtask_id}", resource=resource, verbs=("GET",), ttl_s=ttl_s)

    # -- verify -------------------------------------------------------------------
    def verify(self, raw: str | Token, resource: str = "*", verb: str = "GET") -> dict:
        raw = raw.raw if isinstance(raw, Token) else raw
        try:
            payload, sig = raw.rsplit(".", 1)
        except (ValueError, AttributeError):
            raise TokenError("malformed token") from None
        if not hmac.compare_digest(sig, self._sign(payload)):
            raise TokenError("bad token signature")
        try:
            claims = json.loads(base64.urlsafe_b64decode(payload.encode()).decode())
        except Exception:
            raise TokenError("undecodable token payload") from None
        if claims.get("jti") in self._revoked:
            raise TokenError("token revoked")
        if time.time() > float(claims.get("exp", 0)) + _SKEW:
            raise TokenError("token expired")
        if verb not in claims.get("verbs", []):
            raise TokenError(f"token not valid for {verb}")
        scope = claims.get("res", "")
        if scope != "*" and not _resource_match(scope, resource):
            raise TokenError(f"token scoped to {scope!r}, not {resource!r}")
        return claims

    def revoke(self, token: str | Token) -> None:
        raw = token.raw if isinstance(token, Token) else token
        try:
            payload, _ = raw.rsplit(".", 1)
            claims = json.loads(base64.urlsafe_b64decode(payload.encode()).decode())
            self._revoked.add(claims.get("jti"))
        except Exception:  # revoking garbage is a no-op
            pass

    def _sign(self, payload: str) -> str:
        return hmac.new(self.secret, payload.encode(), hashlib.sha256).hexdigest()


def _resource_match(scope: str, resource: str) -> bool:
    """Prefix scoping: a token for /ds matches /ds and /ds/sub/file."""
    scope = scope.rstrip("/")
    resource = resource.rstrip("/")
    return resource == scope or resource.startswith(scope + "/")
