"""Memory-budgeted spill-to-disk for pipeline breakers (grace hash).

DACP's reverse supply makes a faird server run COOK computation over data
sized by *remote* domains, so the build-side state of the two pipeline
breakers — the aggregate fold's ``GroupState`` and the join build's hash
table — must not grow unbounded with input the operator never chose.  This
module supplies the pieces the executor uses to keep every breaker inside a
shared byte budget:

  * ``MemoryAccountant`` — one per executor run, shared by all concurrent
    pipelines; breakers account their state bytes against the configured
    ``memory_budget`` and switch to grace-hash mode when the *combined*
    usage crosses it.  It also carries the run's spill counters
    (partitions/batches/bytes written, recursion depth), exported through
    ``ExecutorStats`` → ``engine.executor_stats()`` → PING.
  * ``SpillFile`` / ``SpillSet`` — partitioned spill files that reuse the
    RecordBatch **wire framing** (SCHEMA frame, BATCH frames with the
    writev-style zero-copy buffer parts, END frame): a spilled batch
    round-trips through exactly the serialization the transport already
    exercises, and partition readers stream batches back morsel-sized.
  * value-consistent **key hashing** (``partition_ids``) — rows are
    partitioned by a salted hash of their key *values* under python
    equality semantics (int 5 == 5.0 == np.int32(5), ``-0.0 == 0.0``,
    masked keys are one null class), so two rows that would land in the
    same group / join match can never be split across partitions.  Hash
    collisions merely co-locate unrelated keys — never a correctness
    hazard.  Each recursion level re-salts the hash so an oversized
    partition actually splits.
  * ``GraceHashAggregate`` — the aggregate breaker's spill mode.  It spills
    **partial GroupStates** (one state batch per morsel, scattered by key
    hash) rather than raw rows: per-group accumulator merge order is then
    exactly the in-memory morsel order, so results — including float partial
    sums — are **byte-identical** to in-memory execution.  Every state row
    carries a monotone first-seen id; after per-partition re-aggregation the
    groups are re-sorted by the minimum id, reproducing the in-memory
    first-seen group order bit-for-bit.  A partition that still exceeds the
    budget is recursively re-partitioned with the next hash salt.
  * grace-hash join (``collect_build`` / ``spilled_join_stream``) — the
    build side scatters to partitions once its accounted bytes cross the
    budget; the probe side then scatters too (rows tagged with a global row
    id), partition pairs are joined one at a time (recursively re-split if
    a build partition is still too big), and the output is restored to the
    in-memory probe-order by a stable sort on the row ids — byte-identical
    collected results.

The Pallas ``segment_reduce`` path composes with spilling untouched: the
per-morsel folds that produce the partial states still dispatch to the
accelerator through the backend registry; only the (already vectorized,
bit-exactness-critical) state *merges* stay on numpy.
"""

from __future__ import annotations

import os
import tempfile
import threading
import zlib

import numpy as np

from repro.core import dtypes
from repro.core.batch import Column, RecordBatch, concat_batches
from repro.core.errors import PlanError
from repro.core.operators import (
    GroupState,
    build_join_table,
    join_probe_indices,
)
from repro.core.schema import Field, Schema
from repro.transport import framing
from repro.transport.framing import FrameReader, FrameWriter

__all__ = [
    "MemoryAccountant",
    "SpillFile",
    "SpillSet",
    "GraceHashAggregate",
    "collect_build",
    "spilled_join_stream",
    "key_hashes",
    "partition_ids",
    "SPILL_MAX_DEPTH",
    "DEFAULT_SPILL_FANOUT",
    "FS_COL",
    "ROWID_COL",
]

SPILL_MAX_DEPTH = 8
DEFAULT_SPILL_FANOUT = 8
# reserved column names the spill paths append to batches in flight
FS_COL = "__dacp_fs"  # first-seen id riding on aggregate state batches
ROWID_COL = "__dacp_rowid"  # global probe row id riding on join probe batches

_I64MAX = np.iinfo(np.int64).max
# estimated python-side bytes per join hash-table row (dict slot + key tuple
# + index list entry) added on top of the raw build batch bytes
_TABLE_ROW_OVERHEAD = 96


# ---------------------------------------------------------------------------
# memory accounting (shared across the run's concurrent pipelines)
# ---------------------------------------------------------------------------
class MemoryAccountant:
    """Byte budget shared by every breaker of one executor run.

    ``budget <= 0`` disables spilling (unbounded, the default).  Breakers
    ``adjust()`` their accounted state bytes as they grow and check
    ``over()``; whichever breaker observes the combined total above budget
    spills *its own* state.  The trigger point may vary run-to-run under
    concurrency — results never do (spilled execution is byte-identical).

    Doubles as the run's spill observability: counters land in
    ``ExecutorStats.to_dict()["spill"]`` and the server PING response.
    """

    def __init__(self, budget: int = 0):
        self.budget = max(0, int(budget))
        self._lock = threading.Lock()
        self._used = 0
        self.spills = 0  # breakers that switched to grace-hash mode
        self.partitions_written = 0  # spill partition files created
        self.batches_spilled = 0
        self.bytes_spilled = 0  # framed bytes written to spill files
        self.max_depth = 0  # deepest recursive re-partition level

    @property
    def enabled(self) -> bool:
        return self.budget > 0

    def used(self) -> int:
        return self._used

    def adjust(self, delta: int) -> None:
        with self._lock:
            self._used = max(0, self._used + int(delta))

    def over(self) -> bool:
        return self.enabled and self._used > self.budget

    def note_spill(self) -> None:
        with self._lock:
            self.spills += 1

    def note_partition(self) -> None:
        with self._lock:
            self.partitions_written += 1

    def note_batch(self, nbytes: int) -> None:
        with self._lock:
            self.batches_spilled += 1
            self.bytes_spilled += int(nbytes)

    def note_depth(self, depth: int) -> None:
        with self._lock:
            if depth > self.max_depth:
                self.max_depth = depth

    def to_dict(self) -> dict:
        with self._lock:
            return {
                "memory_budget": self.budget,
                "used_bytes": self._used,
                "spills": self.spills,
                "partitions_written": self.partitions_written,
                "batches_spilled": self.batches_spilled,
                "bytes_spilled": self.bytes_spilled,
                "max_depth": self.max_depth,
            }


# ---------------------------------------------------------------------------
# value-consistent key hashing
# ---------------------------------------------------------------------------
_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_NULL_BITS = np.uint64(0x6E756C6C6B657900)  # distinct class for masked keys
_NAN_BITS = np.uint64(0x7FF8000000000000)


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer (vectorized, wrapping uint64 arithmetic)."""
    with np.errstate(over="ignore"):
        x = np.asarray(x, np.uint64)
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return x ^ (x >> np.uint64(31))


def _column_bits(col: Column, n: int) -> np.ndarray:
    """Per-row uint64 fingerprints under python value-equality semantics:
    equal key values (across integer widths, bool vs int, integral floats
    vs ints, str content) get equal bits; ``-0.0`` folds onto ``0.0`` and
    every NaN onto one class (NaN keys never *match* anything, so merging
    their partitions is harmless); masked (null) rows are one class."""
    if col.dtype.is_varwidth:
        bits = np.empty(n, np.uint64)
        data = memoryview(np.ascontiguousarray(col.data))
        off = col.offsets
        for i in range(n):
            bits[i] = zlib.crc32(data[off[i] : off[i + 1]])
    else:
        v = col.values
        k = v.dtype.kind
        if k == "f":
            f = v.astype(np.float64)  # exact for f16/f32
            with np.errstate(invalid="ignore"):
                # integral floats hash as their integer value (python
                # equality: 5.0 == 5) across the FULL integer-key range
                # [-2^63, 2^64) — an exactly-representable 2.0**63 must
                # land with the uint64 key 2**63, not with its float bits
                integral = np.isfinite(f) & (np.floor(f) == f) & (f >= -(2.0**63)) & (f < 2.0**64)
                neg = f < 0
                as_pos = np.where(integral & ~neg, f, 0.0).astype(np.uint64)
                as_neg = np.where(integral & neg, f, 0.0).astype(np.int64).view(np.uint64)
            as_int = np.where(neg, as_neg, as_pos)
            f = f + 0.0  # -0.0 -> +0.0
            fbits = f.view(np.uint64).copy()
            fbits[np.isnan(f)] = _NAN_BITS
            bits = np.where(integral, as_int, fbits)
        elif k == "u" and v.dtype.itemsize == 8:
            bits = v.astype(np.uint64)  # value mod 2^64, same as int64 view
        else:  # signed ints, narrow unsigned, bool — hash the python value
            bits = v.astype(np.int64).view(np.uint64)
    if col.validity is not None:
        bits = np.where(col.validity, bits, _NULL_BITS)
    return bits


def key_hashes(batch: RecordBatch, keys: list, level: int) -> np.ndarray:
    """Salted per-row key hash; a different ``level`` re-salts so recursive
    re-partitioning actually splits an oversized partition."""
    with np.errstate(over="ignore"):
        salt = _mix64(np.uint64(level + 1) * _GOLDEN)
        h = np.full(batch.num_rows, salt, np.uint64)
        for k in keys:
            h = _mix64(h ^ (_column_bits(batch.column(k), batch.num_rows) + _GOLDEN))
    return h


def partition_ids(batch: RecordBatch, keys: list, nparts: int, level: int) -> np.ndarray:
    return (key_hashes(batch, keys, level) % np.uint64(nparts)).astype(np.int64)


# ---------------------------------------------------------------------------
# wire-framed spill files
# ---------------------------------------------------------------------------
class SpillFile:
    """One spill partition: a temp file of wire frames (SCHEMA, BATCH*,
    END).  Batches round-trip through ``RecordBatch.to_buffers`` /
    ``from_buffers`` — the exact zero-copy framing the transport uses, no
    new serialization format.  ``read`` streams batches back (re-sliced to
    ``morsel_rows``) from a fresh read handle; ``close`` deletes the file."""

    def __init__(self, schema: Schema, spill_dir: str | None = None, tag: str = "spill"):
        fd, self.path = tempfile.mkstemp(prefix=f"dacp-{tag}-", suffix=".spill", dir=spill_dir)
        self._f = os.fdopen(fd, "w+b")
        self._writer = FrameWriter(self._f)
        self.schema = schema
        self._writer.write_frame(framing.SCHEMA, {"schema": schema.to_json()})
        self.batches = 0
        self.rows = 0
        self._sealed = False
        self._closed = False

    @property
    def bytes_written(self) -> int:
        return self._writer.bytes_written

    def write(self, batch: RecordBatch) -> None:
        if self._sealed or self._closed:
            raise PlanError("spill partition is sealed; cannot append")
        header, bufs = batch.to_buffers()
        self._writer.write_frame(framing.BATCH, header, RecordBatch.payload_parts(bufs))
        self.batches += 1
        self.rows += batch.num_rows

    def seal(self) -> None:
        if not self._sealed and not self._closed:
            self._writer.write_frame(framing.END, {"rows": self.rows})
            self._f.flush()
            self._sealed = True

    def read(self, morsel_rows: int | None = None):
        """Generator of the spilled batches, morsel-sized."""
        if self._closed:
            raise PlanError("spill partition already consumed/closed")
        self.seal()
        with open(self.path, "rb") as rf:
            fr = FrameReader(rf)
            ftype, header, _body = fr.read_frame()
            if ftype != framing.SCHEMA:  # pragma: no cover - writer invariant
                raise PlanError("spill file does not start with a SCHEMA frame")
            schema = Schema.from_json(header["schema"])
            while True:
                ftype, header, body = fr.read_frame()
                if ftype == framing.END:
                    return
                b = RecordBatch.from_buffers(schema, header, body)
                if morsel_rows and b.num_rows > morsel_rows:
                    for s in range(0, b.num_rows, morsel_rows):
                        yield b.slice(s, s + morsel_rows)
                else:
                    yield b

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._f.close()
        except OSError:  # pragma: no cover - best-effort cleanup
            pass
        try:
            os.unlink(self.path)
        except OSError:  # pragma: no cover - already removed
            pass


class SpillSet:
    """A fan of ``nparts`` partition spill files for one breaker level.
    ``scatter`` splits a batch by key hash and appends each slice to its
    partition (files are created lazily, so empty partitions cost nothing)."""

    def __init__(
        self,
        schema: Schema,
        keys: list,
        nparts: int,
        acct: MemoryAccountant,
        level: int = 0,
        spill_dir: str | None = None,
        tag: str = "spill",
    ):
        self.schema = schema
        self.keys = list(keys)
        self.nparts = int(nparts)
        self.acct = acct
        self.level = level
        self.spill_dir = spill_dir
        self.tag = tag
        self.files: list = [None] * self.nparts

    def scatter(self, batch: RecordBatch) -> None:
        n = batch.num_rows
        if n == 0:
            return
        pids = partition_ids(batch, self.keys, self.nparts, self.level)
        for p in np.unique(pids):
            idx = np.flatnonzero(pids == p)
            part = batch if len(idx) == n else batch.take(idx)
            f = self.files[p]
            if f is None:
                f = self.files[p] = SpillFile(self.schema, self.spill_dir, tag=f"{self.tag}-l{self.level}-p{p}")
                self.acct.note_partition()
            before = f.bytes_written
            f.write(part)
            self.acct.note_batch(f.bytes_written - before)

    def close(self) -> None:
        for f in self.files:
            if f is not None:
                f.close()


# ---------------------------------------------------------------------------
# grace-hash aggregation
# ---------------------------------------------------------------------------
class GraceHashAggregate:
    """Spill mode of the aggregate breaker (see the module docstring for the
    byte-identity argument).  Lifecycle: the executor's aggregate consumer
    creates one when the accounted ``GroupState`` bytes cross the budget,
    feeds it the prefix state and then every further per-morsel partial
    state (``spill_state``), and finally asks for the merged, first-seen
    ordered ``result()``.  ``close()`` removes every spill file."""

    def __init__(
        self,
        keys: list,
        aggs: dict,
        mode: str,
        in_schema: Schema,
        out_schema: Schema,
        acct: MemoryAccountant,
        backend=None,
        morsel_rows: int = 65536,
        fanout: int = DEFAULT_SPILL_FANOUT,
        spill_dir: str | None = None,
    ):
        self.keys = list(keys)
        self.aggs = dict(aggs)
        self.mode = mode
        self.in_schema = in_schema
        self.out_schema = out_schema
        self.acct = acct
        self.backend = backend
        self.morsel_rows = max(1, int(morsel_rows))
        self.fanout = max(2, int(fanout))
        self.spill_dir = spill_dir
        self._fs_next = 0
        self._state_fields = self._make_state_fields()
        self._state_schema = Schema(self._state_fields)
        self._sets: list = []
        self._set = self._new_set(0)
        acct.note_spill()

    # -- eligibility --------------------------------------------------------
    @staticmethod
    def supported(keys: list, aggs: dict, mode: str, in_schema: Schema) -> bool:
        """Spilling needs ≥1 key (a keyless aggregate is a single bounded
        group) and a collision-free state-batch schema."""
        if not keys:
            return False
        probe = GroupState(keys, aggs, mode, in_schema)
        state_names = set(probe._state_specs())
        names = set(keys) | state_names | {FS_COL}
        return len(names) == len(keys) + len(state_names) + 1

    def _make_state_fields(self) -> list:
        fields = [self.in_schema.field(k) for k in self.keys]
        probe = GroupState(self.keys, self.aggs, self.mode, self.in_schema)
        for name, (_init, dt) in probe._state_specs().items():
            fields.append(Field(name, dtypes.from_numpy(np.dtype(dt))))
        fields.append(Field(FS_COL, dtypes.resolve("int64")))
        return fields

    def _new_set(self, level: int) -> SpillSet:
        s = SpillSet(
            self._state_schema, self.keys, self.fanout, self.acct, level=level, spill_dir=self.spill_dir, tag="agg"
        )
        self._sets.append(s)
        return s

    # -- state <-> batch ----------------------------------------------------
    def _state_batch(self, st: GroupState, fs: np.ndarray) -> RecordBatch:
        ngroups = len(st.key_rows)
        cols = []
        for i, k in enumerate(self.keys):
            f = self.in_schema.field(k)
            cols.append(st._key_column(f, [row[i] for row in st.key_rows]))
        for name, (_init, dt) in st._state_specs().items():
            cols.append(Column(dtypes.from_numpy(np.dtype(dt)), values=np.ascontiguousarray(st.acc[name][:ngroups])))
        cols.append(Column.from_values(dtypes.resolve("int64"), np.ascontiguousarray(fs[:ngroups])))
        return RecordBatch(self._state_schema, cols)

    def _state_from_batch(self, batch: RecordBatch) -> GroupState:
        """Rehydrate a spilled state batch into a GroupState shell so the
        partition fold reuses the exact in-memory ``merge`` arithmetic."""
        st = GroupState(self.keys, self.aggs, self.mode, self.in_schema)
        key_cols = [batch.column(k) for k in self.keys]
        st.key_rows = list(zip(*[c.to_pylist() for c in key_cols]))
        st.gids = {kt: i for i, kt in enumerate(st.key_rows)}
        for name in st.acc:
            st.acc[name] = np.asarray(batch.column(name).values)
        return st

    # -- spill-side API -----------------------------------------------------
    def spill_state(self, st: GroupState) -> None:
        """Scatter one partial state (morsel fold or the in-memory prefix)
        to the level-0 partitions, assigning monotone first-seen ids."""
        ngroups = len(st.key_rows)
        if ngroups == 0:
            return
        fs = np.arange(self._fs_next, self._fs_next + ngroups, dtype=np.int64)
        self._fs_next += ngroups
        self._set.scatter(self._state_batch(st, fs))

    def result(self) -> RecordBatch:
        leaves: list = []
        for f in self._set.files:
            if f is not None:
                self._process(f, 0, leaves)
        if not leaves:
            return RecordBatch.empty(self.out_schema)
        cat = concat_batches([b for b, _fs in leaves])
        fs = np.concatenate([f for _b, f in leaves])
        return cat.take(np.argsort(fs, kind="stable"))

    def _absorb(self, total: GroupState, fs: np.ndarray, batch: RecordBatch) -> np.ndarray:
        other = self._state_from_batch(batch)
        bfs = np.asarray(batch.column(FS_COL).values)
        idx = total.merge_indexed(other)
        grow = len(total.gids) - len(fs)
        if grow > 0:
            fs = np.concatenate([fs, np.full(grow, _I64MAX, np.int64)])
        np.minimum.at(fs, idx, bfs)
        return fs

    def _process(self, f: SpillFile, level: int, leaves: list) -> None:
        """Fold one partition's state batches (in spill order — the morsel
        order) into a fresh GroupState; recursively re-partition when the
        partition itself exceeds the budget."""
        self.acct.note_depth(level)
        total = GroupState(self.keys, self.aggs, self.mode, self.in_schema, vectorized=True, backend=self.backend)
        fs = np.zeros(0, np.int64)
        reserved = 0
        try:
            reader = f.read(self.morsel_rows)
            for batch in reader:
                fs = self._absorb(total, fs, batch)
                nb = total.approx_nbytes()
                self.acct.adjust(nb - reserved)
                reserved = nb
                if self.acct.over() and level + 1 < SPILL_MAX_DEPTH and len(total.gids) > 1:
                    sub = self._new_set(level + 1)
                    sub.scatter(self._state_batch(total, fs))
                    total = None
                    self.acct.adjust(-reserved)
                    reserved = 0
                    for rest in reader:
                        sub.scatter(rest)
                    f.close()
                    for sf in sub.files:
                        if sf is not None:
                            self._process(sf, level + 1, leaves)
                    return
            leaves.append((total.result(self.out_schema), fs))
        finally:
            self.acct.adjust(-reserved)
            f.close()

    def close(self) -> None:
        for s in self._sets:
            s.close()


# ---------------------------------------------------------------------------
# grace-hash join
# ---------------------------------------------------------------------------
def collect_build(
    batches,
    schema: Schema,
    on: list,
    acct: MemoryAccountant,
    fanout: int = DEFAULT_SPILL_FANOUT,
    spill_dir: str | None = None,
):
    """Materialize a join build side under the accountant.

    Returns ``("mem", build_batch, table)`` when it fits (the table's bytes
    stay accounted for the rest of the run — it lives as long as the
    pipeline), or ``("spill", SpillSet)`` once the accounted bytes cross
    the budget: the already-collected batches and the rest of the stream
    are scattered to build partitions by join-key hash."""
    got: list = []
    reserved = 0
    sset = None
    try:
        for b in batches:
            if sset is not None:
                sset.scatter(b)
                continue
            got.append(b)
            delta = b.nbytes + _TABLE_ROW_OVERHEAD * b.num_rows
            reserved += delta
            acct.adjust(delta)
            if acct.over():
                acct.note_spill()
                sset = SpillSet(schema, on, fanout, acct, level=0, spill_dir=spill_dir, tag="join-build")
                for g in got:
                    sset.scatter(g)
                got = []
                acct.adjust(-reserved)
                reserved = 0
    except BaseException:
        # a failing build source (e.g. a dead exchange pull) must not strand
        # partition files on a long-lived server
        acct.adjust(-reserved)
        if sset is not None:
            sset.close()
        raise
    if sset is not None:
        return ("spill", sset)
    rb = concat_batches(got) if got else RecordBatch.empty(schema)
    return ("mem", rb, build_join_table(rb, on))


def spilled_join_stream(
    build_set: SpillSet,
    probe_batches,
    on: list,
    payload: list,
    out_schema: Schema,
    probe_schema: Schema,
    acct: MemoryAccountant,
    morsel_rows: int = 65536,
    fanout: int = DEFAULT_SPILL_FANOUT,
    spill_dir: str | None = None,
):
    """Drive a join whose build side spilled: scatter the probe stream by
    the same key hash (tagging rows with a global row id), join partition
    pairs one at a time, and emit the matches re-sorted to the exact
    in-memory probe order (stable sort on the row ids — within one probe
    row, build matches are already in build order)."""
    rowid_field = Field(ROWID_COL, dtypes.resolve("int64"))
    pset = SpillSet(
        probe_schema.append(rowid_field), on, build_set.nparts, acct, level=build_set.level, spill_dir=spill_dir, tag="join-probe"
    )
    try:
        next_rowid = 0
        for b in probe_batches:
            rid = Column.from_values(dtypes.resolve("int64"), np.arange(next_rowid, next_rowid + b.num_rows, dtype=np.int64))
            next_rowid += b.num_rows
            pset.scatter(b.with_column(rowid_field, rid))
        outs: list = []
        for bf, pf in zip(build_set.files, pset.files):
            _join_pair(bf, pf, build_set.level, outs, on, payload, out_schema, probe_schema, acct, morsel_rows, fanout, spill_dir)
        if not outs:
            return
        cat = concat_batches([b for b, _r in outs])
        rid = np.concatenate([r for _b, r in outs])
        out = cat.take(np.argsort(rid, kind="stable"))
        for s in range(0, out.num_rows, morsel_rows):
            yield out.slice(s, s + morsel_rows)
    finally:
        build_set.close()
        pset.close()


def _join_pair(
    bf: SpillFile | None,
    pf: SpillFile | None,
    level: int,
    outs: list,
    on: list,
    payload: list,
    out_schema: Schema,
    probe_schema: Schema,
    acct: MemoryAccountant,
    morsel_rows: int,
    fanout: int,
    spill_dir: str | None,
    force_mem: bool = False,
) -> None:
    """Join one (build partition, probe partition) pair, recursively
    re-splitting the pair while the build side still exceeds the budget.
    ``force_mem`` (set when the previous level's scatter failed to split —
    one dominant key class) builds in memory instead of rewriting the same
    bytes to every remaining level."""
    if bf is None or pf is None:
        # an equi-join emits nothing for a key class missing on either side
        if bf is not None:
            bf.close()
        if pf is not None:
            pf.close()
        return
    acct.note_depth(level)
    batches: list = []
    reserved = 0
    try:
        reader = bf.read(morsel_rows)
        for b in reader:
            batches.append(b)
            delta = b.nbytes + _TABLE_ROW_OVERHEAD * b.num_rows
            reserved += delta
            acct.adjust(delta)
            if acct.over() and level + 1 < SPILL_MAX_DEPTH and not force_mem:
                bsub = SpillSet(bf.schema, on, fanout, acct, level=level + 1, spill_dir=spill_dir, tag="join-build")
                psub = SpillSet(pf.schema, on, fanout, acct, level=level + 1, spill_dir=spill_dir, tag="join-probe")
                try:
                    for g in batches:
                        bsub.scatter(g)
                    for g in reader:
                        bsub.scatter(g)
                    batches = []
                    acct.adjust(-reserved)
                    reserved = 0
                    bf.close()
                    # progress guard: if everything re-hashed into a single
                    # sub-partition, splitting again cannot help
                    no_split = sum(1 for f in bsub.files if f is not None) <= 1
                    for g in pf.read(morsel_rows):
                        psub.scatter(g)
                    pf.close()
                    for sb, sp in zip(bsub.files, psub.files):
                        _join_pair(
                            sb, sp, level + 1, outs, on, payload, out_schema, probe_schema,
                            acct, morsel_rows, fanout, spill_dir, force_mem=no_split,
                        )
                finally:
                    bsub.close()
                    psub.close()
                return
        rb = concat_batches(batches) if batches else RecordBatch.empty(bf.schema)
        table = build_join_table(rb, on)
        for pb in pf.read(morsel_rows):
            rid = np.asarray(pb.column(ROWID_COL).values)
            core = pb.select(probe_schema.names)
            lidx, ridx = join_probe_indices(core, table, on)
            if len(lidx) == 0:
                continue
            lpart = core.take(lidx)
            rpart = rb.take(ridx)
            cols = list(lpart.columns) + [rpart.column(name) for name in payload]
            outs.append((RecordBatch(out_schema, cols), rid[lidx]))
    finally:
        acct.adjust(-reserved)
        bf.close()
        pf.close()
