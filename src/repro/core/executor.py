"""Morsel-driven parallel pipeline driver (paper §III-D: "as fast as the
hardware allows").

``execute_parallel`` compiles an (optimized) COOK DAG into **pipelines** —
maximal chains of morsel-pure operators (filter/select/project/map)
separated by **pipeline breakers** (aggregate build, join build).  Each
pipeline's source stream is cut into *morsels* (RecordBatch slices of
``morsel_rows``) that a pool of worker threads drains concurrently; results
are reassembled **in input order** through a bounded reorder window, which
doubles as backpressure: workers stop pulling new morsels when the consumer
falls more than ``window`` morsels behind.  Output batches therefore stream
to the caller as they are produced — the first batch is yielded while later
morsels are still being scanned/computed, preserving SDF streaming
semantics, and results are byte-deterministic for a given morsel size
regardless of worker count.

Breakers:

  * ``aggregate`` — each worker folds its morsel into a private
    ``GroupState`` (vectorized factorization); the consumer merges the
    partial states in morsel order, so group order matches the reference
    single-threaded pull chain.
  * ``join`` — the build side runs as its own parallel stage to a
    materialized hash table (built once, shared read-only); probing is
    morsel-pure and stays inside the probe pipeline.
  * ``limit`` / ``rebatch`` — inherently sequential; they run as a serial
    tail over the (already parallel) upstream stage via the reference
    evaluators.

Every pipeline source is wrapped in a bounded **prefetcher** thread started
at stage activation, so scans and cross-domain exchange pulls overlap with
compute — and union branches pull their exchanges concurrently instead of
serially (the scheduler's network/compute overlap).

Compute is delegated to a pluggable backend (``repro.core.backend``):
adjacent Filter→Select pairs are peephole-fused into the backend's
``filter_select`` kernel, which the pallas backend dispatches to the
TPU kernels in ``repro.kernels`` when the morsel is eligible.

Laziness contract: building the executor does no work; worker threads spin
up on the first pull of the output SDF and wind down when it is exhausted
or closed.
"""

from __future__ import annotations

import os
import queue
import threading
from dataclasses import dataclass, field
from typing import Callable, Iterator

from repro.core.backend import ComputeBackend, get_backend
from repro.core.batch import RecordBatch, concat_batches
from repro.core.dag import Dag, Node
from repro.core.errors import PlanError, SchemaError
from repro.core.operators import (
    GroupState,
    agg_out_fields,
    build_join_table,
    execute_node,
    filter_morsel,
    get_map,
    join_probe_morsel,
    join_schema,
    map_morsel,
    project_morsel,
    project_schema,
    select_morsel,
)
from repro.core.schema import Schema
from repro.core.sdf import StreamingDataFrame

__all__ = ["ExecutorConfig", "execute_parallel", "prefetch_sdf", "default_workers"]

DEFAULT_MORSEL_ROWS = 65536
_STREAMING_OPS = ("filter", "select", "project", "map")


def default_workers() -> int:
    env = os.environ.get("DACP_EXECUTOR_WORKERS")
    if env:
        return max(0, int(env))
    return min(4, os.cpu_count() or 1)


@dataclass
class ExecutorConfig:
    """Executor tuning knobs (engine/server-level configuration).

    num_workers   morsel worker threads per pipeline stage; 1 = sequential
                  in-line execution (no threads), 0 = delegate to the
                  reference pull chain (``operators.execute``).
    morsel_rows   rows per morsel (source batches are sliced to this).
    backend       compute backend name ("numpy" | "pallas" | "auto").
    window        reorder/backpressure window in morsels (0 → 4×workers).
    prefetch_batches  per-source prefetch queue depth (0 disables).
    stream_depth  producer-queue depth used by the server when streaming
                  result frames (faird GET/COOK overlap; 0 disables).
    scan_workers  parallel file readers inside datasource scans.
    """

    num_workers: int = field(default_factory=default_workers)
    morsel_rows: int = field(default_factory=lambda: int(os.environ.get("DACP_MORSEL_ROWS", DEFAULT_MORSEL_ROWS)))
    backend: str = field(default_factory=lambda: os.environ.get("DACP_BACKEND", "auto"))
    window: int = 0
    prefetch_batches: int = 4
    stream_depth: int = 4
    scan_workers: int = field(default_factory=lambda: int(os.environ.get("DACP_SCAN_WORKERS", "4")))

    def effective_window(self) -> int:
        return self.window if self.window > 0 else 4 * max(1, self.num_workers)


# ---------------------------------------------------------------------------
# bounded source prefetch (network/disk ↔ compute overlap)
# ---------------------------------------------------------------------------
_DONE = object()


class _Prefetch:
    """Pulls an SDF's batches on a background thread into a bounded queue.
    Exceptions (e.g. a dead exchange pull) are re-raised to the consumer
    with their original type, so upstream resilience/retry still works."""

    def __init__(self, sdf: StreamingDataFrame, depth: int):
        self._sdf = sdf
        self._q: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._stop = False
        self._exc: BaseException | None = None
        self._thread: threading.Thread | None = None

    def start(self) -> None:
        if self._thread is None:
            self._thread = threading.Thread(target=self._run, daemon=True)
            self._thread.start()

    def _run(self) -> None:
        try:
            for b in self._sdf.iter_batches():
                if not self._put(b):
                    return
        except BaseException as e:  # noqa: BLE001 - re-raised on the consumer side
            self._exc = e
        self._put(_DONE)

    def _put(self, item) -> bool:
        while not self._stop:
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def __iter__(self) -> Iterator[RecordBatch]:
        self.start()
        while not self._stop:
            try:
                item = self._q.get(timeout=0.1)
            except queue.Empty:
                continue
            if item is _DONE:
                if self._exc is not None:
                    raise self._exc
                return
            yield item

    def close(self) -> None:
        self._stop = True


def prefetch_sdf(sdf: StreamingDataFrame, depth: int = 4) -> StreamingDataFrame:
    """Producer-queue wrapper: batches are computed ``depth`` ahead of the
    consumer on a background thread (the server uses this to overlap result
    production with socket writes)."""
    if depth <= 0:
        return sdf

    def gen():
        pf = _Prefetch(sdf, depth)
        try:
            yield from pf
        finally:
            pf.close()

    return StreamingDataFrame(sdf.schema, gen)


# ---------------------------------------------------------------------------
# ordered morsel runs
# ---------------------------------------------------------------------------
class _Branch:
    """One pipeline input: a source SDF plus the op specs applied to its
    morsels.  Unions contribute several branches to the same stage."""

    __slots__ = ("sdf", "specs")

    def __init__(self, sdf: StreamingDataFrame, specs: list | None = None):
        self.sdf = sdf
        self.specs = specs if specs is not None else []


def _apply_ops(ops: list, batch: RecordBatch) -> RecordBatch | None:
    for op in ops:
        batch = op(batch)
        if batch is None:
            return None
    return batch


def _morsel_slices(batch: RecordBatch, morsel_rows: int):
    if batch.num_rows <= morsel_rows:
        yield batch
        return
    for s in range(0, batch.num_rows, morsel_rows):
        yield batch.slice(s, s + morsel_rows)


def _run_ordered(branches: list, cfg: ExecutorConfig, backend: ComputeBackend, make_item: Callable):
    """Drive branches' morsels through a worker pool; yield non-None
    ``make_item(ops, morsel)`` results in strict input order.

    With ``num_workers <= 1`` this degrades to a fully synchronous loop —
    no threads, reference pull-chain behavior."""
    compiled = [(br, _finalize_ops(br.specs, backend)) for br in branches]

    if cfg.num_workers <= 1:
        for br, ops in compiled:
            for batch in br.sdf.iter_batches():
                for m in _morsel_slices(batch, cfg.morsel_rows):
                    out = make_item(ops, m)
                    if out is not None:
                        yield out
        return

    window = cfg.effective_window()
    prefetchers = [_Prefetch(br.sdf, cfg.prefetch_batches) for br, _ in compiled]
    for pf in prefetchers:
        pf.start()  # all sources (incl. every exchange pull) activate now

    def morsels():
        for (_, ops), pf in zip(compiled, prefetchers):
            for batch in pf:
                for m in _morsel_slices(batch, cfg.morsel_rows):
                    yield ops, m

    it = morsels()
    src_lock = threading.Lock()
    cond = threading.Condition()
    state = {"assigned": 0, "next": 0, "total": None, "error": None, "stop": False, "buf": {}}

    def worker():
        while True:
            with cond:
                while (
                    not state["stop"]
                    and state["error"] is None
                    and state["assigned"] - state["next"] >= window
                ):
                    cond.wait()
                if state["stop"] or state["error"] is not None:
                    return
            with src_lock:
                if state["total"] is not None:
                    return
                try:
                    ops, m = next(it)
                except StopIteration:
                    state["total"] = state["assigned"]
                    with cond:
                        cond.notify_all()
                    return
                except BaseException as e:  # noqa: BLE001 - surfaced to consumer
                    with cond:
                        if state["error"] is None:
                            state["error"] = e
                        state["total"] = state["assigned"]
                        cond.notify_all()
                    return
                seq = state["assigned"]
                state["assigned"] = seq + 1
            try:
                out = make_item(ops, m)
            except BaseException as e:  # noqa: BLE001 - surfaced to consumer
                with cond:
                    if state["error"] is None:
                        state["error"] = e
                    cond.notify_all()
                return
            with cond:
                state["buf"][seq] = out
                cond.notify_all()

    threads = [threading.Thread(target=worker, daemon=True) for _ in range(cfg.num_workers)]
    for t in threads:
        t.start()
    try:
        while True:
            with cond:
                while (
                    state["next"] not in state["buf"]
                    and state["error"] is None
                    and not (state["total"] is not None and state["next"] >= state["total"])
                ):
                    cond.wait(timeout=0.1)
                if state["error"] is not None:
                    raise state["error"]
                if state["next"] not in state["buf"]:
                    return  # total reached: all morsels emitted
                item = state["buf"].pop(state["next"])
                state["next"] += 1
                cond.notify_all()
            if item is not None:
                yield item
    finally:
        with cond:
            state["stop"] = True
            cond.notify_all()
        for pf in prefetchers:
            pf.close()


# ---------------------------------------------------------------------------
# op-spec finalization (backend binding + filter→select fusion)
# ---------------------------------------------------------------------------
def _finalize_ops(specs: list, backend: ComputeBackend) -> list:
    """Turn compile-time op specs into morsel closures, peephole-fusing
    adjacent filter+select into the backend's fused kernel."""
    ops: list = []
    i = 0
    while i < len(specs):
        kind, args = specs[i]
        if kind == "filter" and i + 1 < len(specs) and specs[i + 1][0] == "select":
            pred, cols = args[0], list(specs[i + 1][1][0])
            ops.append(lambda b, _p=pred, _c=cols: backend.filter_select(b, _p, _c))
            i += 2
            continue
        if kind == "filter":
            pred = args[0]
            ops.append(lambda b, _p=pred: filter_morsel(b, _p, backend))
        elif kind == "select":
            cols = list(args[0])
            ops.append(lambda b, _c=cols: select_morsel(b, _c))
        elif kind == "project":
            exprs, out_schema = args
            ops.append(lambda b, _e=exprs, _s=out_schema: project_morsel(b, _e, _s))
        elif kind == "map":
            mf, fn_params = args
            ops.append(lambda b, _m=mf, _p=fn_params: map_morsel(b, _m, _p))
        elif kind == "probe":
            once, on, payload, schema = args
            ops.append(
                lambda b, _o=once, _on=on, _pl=payload, _s=schema: join_probe_morsel(
                    b, _o.get()[0], _o.get()[1], _on, _pl, _s
                )
            )
        else:  # pragma: no cover - compiler invariant
            raise PlanError(f"unknown morsel op {kind!r}")
        i += 1
    return ops


class _Once:
    """Thread-safe lazily-computed value (join build table)."""

    def __init__(self, factory: Callable):
        self._factory = factory
        self._lock = threading.Lock()
        self._value = None
        self._ready = False

    def get(self):
        if not self._ready:
            with self._lock:
                if not self._ready:
                    self._value = self._factory()
                    self._ready = True
        return self._value


# ---------------------------------------------------------------------------
# DAG → pipeline compiler
# ---------------------------------------------------------------------------
class _Compiler:
    def __init__(self, dag: Dag, resolver: Callable[[Node], StreamingDataFrame], cfg: ExecutorConfig, backend: ComputeBackend):
        self.dag = dag
        self.resolver = resolver
        self.cfg = cfg
        self.backend = backend
        self._memo: dict = {}  # node id -> (branches, schema)

    def compile(self) -> StreamingDataFrame:
        branches, schema = self._stream(self.dag.output)
        return self._stage_sdf(branches, schema)

    # -- stage assembly -----------------------------------------------------
    def _stage_sdf(self, branches: list, schema: Schema) -> StreamingDataFrame:
        if len(branches) == 1 and not branches[0].specs:
            return branches[0].sdf  # nothing to compute: pass the source through

        def gen():
            yield from _run_ordered(branches, self.cfg, self.backend, _apply_ops)

        return StreamingDataFrame(schema, gen)

    def _collect_stage(self, branches: list, schema: Schema) -> RecordBatch:
        got = list(_run_ordered(branches, self.cfg, self.backend, _apply_ops))
        return concat_batches(got) if got else RecordBatch.empty(schema)

    # -- recursive compilation ---------------------------------------------
    def _stream(self, nid: str) -> tuple:
        memo = self._memo.get(nid)
        if memo is not None:
            branches, schema = memo
            # consumers mutate spec lists; hand each its own copy
            return [_Branch(br.sdf, list(br.specs)) for br in branches], schema
        out = self._compile_node(self.dag.nodes[nid])
        self._memo[nid] = out
        branches, schema = out
        return [_Branch(br.sdf, list(br.specs)) for br in branches], schema

    def _compile_node(self, node: Node) -> tuple:
        op = node.op
        if op in ("source", "exchange"):
            sdf = self.resolver(node)
            return [_Branch(sdf)], sdf.schema
        if op in _STREAMING_OPS:
            branches, schema = self._stream(node.inputs[0])
            spec, schema = self._streaming_spec(node, schema)
            for br in branches:
                br.specs.append(spec)
            return branches, schema
        if op == "union":
            branches, schema = self._stream(node.inputs[0])
            for other in node.inputs[1:]:
                b2, s2 = self._stream(other)
                if not s2.equals(schema):
                    raise SchemaError("union over mismatched schemas")
                branches.extend(b2)
            return branches, schema
        if op == "aggregate":
            return self._compile_aggregate(node)
        if op == "join":
            return self._compile_join(node)
        if op in ("limit", "rebatch"):
            # sequential-by-nature: serial tail over the parallel upstream
            branches, schema = self._stream(node.inputs[0])
            sdf = execute_node(node, [self._stage_sdf(branches, schema)])
            return [_Branch(sdf)], sdf.schema
        raise PlanError(f"operator {op!r} has no parallel evaluator")

    def _streaming_spec(self, node: Node, in_schema: Schema) -> tuple:
        if node.op == "filter":
            return ("filter", (node.params["predicate"],)), in_schema
        if node.op == "select":
            cols = list(node.params["columns"])
            return ("select", (cols,)), in_schema.select(cols)
        if node.op == "project":
            exprs = dict(node.params["exprs"])
            keep = bool(node.params.get("keep", True))
            out_schema = project_schema(in_schema, exprs, keep)
            return ("project", (exprs, out_schema)), out_schema
        if node.op == "map":
            mf = get_map(node.params["fn"])
            fn_params = dict(node.params.get("fn_params", {}))
            return ("map", (mf, fn_params)), mf.schema_fn(in_schema, **fn_params)
        raise PlanError(f"not a streaming op: {node.op!r}")  # pragma: no cover

    def _compile_aggregate(self, node: Node) -> tuple:
        keys = list(node.params["keys"])
        aggs = dict(node.params["aggs"])
        mode = node.params.get("mode", "full")
        branches, in_schema = self._stream(node.inputs[0])
        missing = [k for k in keys if k not in in_schema]
        if missing:
            raise SchemaError(f"aggregate keys missing from input: {missing}")
        out_schema = Schema(agg_out_fields(in_schema, keys, aggs, mode))
        cfg, backend = self.cfg, self.backend

        def fold(ops, morsel):
            b = _apply_ops(ops, morsel)
            if b is None or b.num_rows == 0:
                return None
            st = GroupState(keys, aggs, mode, in_schema, vectorized=True)
            st.update(b)
            return st

        def agg_gen():
            # breaker: fold morsels into per-morsel partial states in
            # parallel, merge them in morsel order (deterministic output)
            total = GroupState(keys, aggs, mode, in_schema, vectorized=True)
            for st in _run_ordered(branches, cfg, backend, fold):
                total.merge(st)
            yield total.result(out_schema)

        return [_Branch(StreamingDataFrame(out_schema, agg_gen))], out_schema

    def _compile_join(self, node: Node) -> tuple:
        on = list(node.params["on"])
        left_branches, ls = self._stream(node.inputs[0])
        right_branches, rs = self._stream(node.inputs[1])
        schema, payload, _rename = join_schema(ls, rs, on)

        def build():
            rb = self._collect_stage(right_branches, rs)
            return rb, build_join_table(rb, on)

        once = _Once(build)
        for br in left_branches:
            br.specs.append(("probe", (once, on, payload, schema)))
        return left_branches, schema


def execute_parallel(
    dag: Dag,
    source_resolver: Callable[[Node], StreamingDataFrame],
    config: ExecutorConfig | None = None,
) -> StreamingDataFrame:
    """Wire the DAG into morsel-parallel pipelines and return the output SDF.

    Semantics match ``operators.execute`` (same rows, same order for a given
    morsel size); execution is lazy — workers start on the first pull."""
    cfg = config or ExecutorConfig()
    backend = get_backend(cfg.backend)
    return _Compiler(dag, source_resolver, cfg, backend).compile()
