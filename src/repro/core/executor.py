"""Morsel-driven parallel pipeline driver (paper §III-D: "as fast as the
hardware allows").

``execute_parallel`` compiles an (optimized) COOK DAG into **pipelines** —
maximal chains of morsel-pure operators (filter/select/project/map)
separated by **pipeline breakers** (aggregate build, join build).  Each
pipeline's source stream is cut into *morsels* (RecordBatch slices of
``morsel_rows``) that a pool of worker threads drains concurrently; results
are reassembled **in input order** through a bounded reorder window, which
doubles as backpressure: workers stop pulling new morsels when the consumer
falls more than ``window`` morsels behind.  Output batches therefore stream
to the caller as they are produced — the first batch is yielded while later
morsels are still being scanned/computed, preserving SDF streaming
semantics, and results are byte-deterministic for a given morsel size
regardless of worker count.

Breakers:

  * ``aggregate`` — each worker folds its morsel into a private
    ``GroupState`` (vectorized factorization); the consumer merges the
    partial states in morsel order, so group order matches the reference
    single-threaded pull chain.
  * ``join`` — the build side runs as its own parallel stage to a
    materialized hash table (built once, shared read-only); probing is
    morsel-pure and stays inside the probe pipeline.
  * ``limit`` / ``rebatch`` — inherently sequential; they run as a serial
    tail over the (already parallel) upstream stage via the reference
    evaluators.

Every pipeline source is wrapped in a bounded **prefetcher** thread started
at stage activation, so scans and cross-domain exchange pulls overlap with
compute — and union branches pull their exchanges concurrently instead of
serially (the scheduler's network/compute overlap).

Compute is delegated to a pluggable backend (``repro.core.backend``):
adjacent Filter→Select pairs are peephole-fused into the backend's
``filter_select`` kernel, projection arithmetic runs through the backend's
``project`` kernel, and aggregate folds hand factorized morsels to the
backend's ``segment_reduce`` kernel — the pallas backend dispatches each to
the TPU kernels in ``repro.kernels`` when the morsel is eligible.

Morsel sizing is either static (``morsel_rows=N``: byte-deterministic
output for a given N regardless of worker count) or adaptive
(``morsel_rows="auto"``: each pipeline tunes its slice size from an EWMA of
observed morsel latency toward ~1 ms/morsel, clamped to [4096, 262144];
row *order* is still deterministic, but float aggregation partial sums may
group differently run-to-run as boundaries move).  Each run's
``ExecutorStats`` (``get_last_stats()``) reports per-pipeline morsel counts
and the tuned size.

Memory budget: ``ExecutorConfig.memory_budget`` (env ``DACP_MEMORY_BUDGET``)
bounds the combined bytes of all breaker build states in a run through a
shared ``MemoryAccountant``.  When an aggregate's merged ``GroupState`` or
a join's collected build side crosses the budget, the breaker switches to
**grace-hash spill** (``repro.core.spill``): state/build batches partition
to wire-framed temp files by key hash and partitions are processed one at a
time (recursively re-partitioned while still over budget) — the morsel
driver, reorder window, and deterministic merge order are untouched, and
results stay byte-identical to in-memory execution.  Spill counters
(partitions/batches/bytes written, recursion depth) ride on
``ExecutorStats`` and the server PING response.

Laziness contract: building the executor does no work; worker threads spin
up on the first pull of the output SDF and wind down when it is exhausted
or closed.
"""

from __future__ import annotations

import itertools
import queue
import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Callable, Iterator

from repro.core.backend import FUSED_INELIGIBLE, ComputeBackend, get_backend, plan_fused_chain
from repro.core.batch import RecordBatch, concat_batches
from repro.core.dag import Dag, Node
from repro.core.env import env_bytes, env_devices, env_dir, env_int, env_morsel_rows, env_str, knob_default
from repro.core.errors import FlowCancelled, PlanError, SchemaError
from repro.core.operators import (
    GroupState,
    agg_out_fields,
    build_join_table,
    execute_node,
    filter_morsel,
    get_map,
    join_probe_morsel,
    join_schema,
    map_morsel,
    project_schema,
    select_morsel,
)
from repro.core.schema import Schema
from repro.core.sdf import StreamingDataFrame
from repro.core.spill import (
    ROWID_COL,
    GraceHashAggregate,
    MemoryAccountant,
    collect_build,
    spilled_join_stream,
)

__all__ = [
    "ExecutorConfig",
    "ExecutorStats",
    "execute_parallel",
    "prefetch_sdf",
    "default_workers",
    "get_last_stats",
]

DEFAULT_MORSEL_ROWS = knob_default("DACP_MORSEL_ROWS")
# adaptive ("auto") morsel sizing envelope: EWMA of observed per-morsel
# latency steers the size toward AUTO_TARGET_S per morsel, clamped.
AUTO_MORSEL_MIN = 4096
AUTO_MORSEL_MAX = 262144
AUTO_MORSEL_INIT = 16384
AUTO_TARGET_S = 1e-3
_STREAMING_OPS = ("filter", "select", "project", "map")


def default_workers() -> int:
    return env_int("DACP_EXECUTOR_WORKERS")


@dataclass
class ExecutorConfig:
    """Executor tuning knobs (engine/server-level configuration).

    num_workers   morsel worker threads per pipeline stage; 1 = sequential
                  in-line execution (no threads), 0 = delegate to the
                  reference pull chain (``operators.execute``).
    morsel_rows   rows per morsel (source batches are sliced to this), or
                  ``"auto"``: each pipeline tunes its own size from an EWMA
                  of observed morsel latency (target ~1 ms/morsel, clamped
                  to [4096, 262144]); the chosen size lands in the run's
                  ``ExecutorStats``.
    backend       compute backend name ("numpy" | "pallas" | "auto").
    window        reorder/backpressure window in morsels (0 → 4×workers).
    prefetch_batches  per-source prefetch queue depth (0 disables).
    stream_depth  producer-queue depth used by the server when streaming
                  result frames (faird GET/COOK overlap; 0 disables).
    scan_workers  parallel file readers inside datasource scans.
    memory_budget combined byte budget for breaker build states (aggregate
                  GroupStates + join build sides) per run; crossing it
                  switches the breaker to grace-hash spill-to-disk.  0 =
                  unbounded (no spilling).  Env ``DACP_MEMORY_BUDGET``
                  accepts ``262144`` / ``256KB`` / ``16m`` forms.
    spill_dir     directory for spill partition files (None = the system
                  temp dir; env ``DACP_SPILL_DIR``).
    spill_fanout  partitions per grace-hash level (≥ 2).
    devices       jax device indices that fused-pipeline stages round-robin
                  their device-resident launches/staged uploads across
                  (None = jax's default device; env ``DACP_DEVICES`` as a
                  comma-separated list, validated with warn + fallback).
    """

    num_workers: int = field(default_factory=default_workers)
    morsel_rows: int | str = field(default_factory=lambda: env_morsel_rows("DACP_MORSEL_ROWS"))
    backend: str = field(default_factory=lambda: env_str("DACP_BACKEND"))
    window: int = 0
    prefetch_batches: int = 4
    stream_depth: int = 4
    scan_workers: int = field(default_factory=lambda: env_int("DACP_SCAN_WORKERS"))
    memory_budget: int = field(default_factory=lambda: env_bytes("DACP_MEMORY_BUDGET"))
    spill_dir: str | None = field(default_factory=lambda: env_dir("DACP_SPILL_DIR"))
    spill_fanout: int = 8
    devices: tuple | None = field(default_factory=lambda: env_devices("DACP_DEVICES"))

    def __post_init__(self) -> None:
        mr = self.morsel_rows
        if isinstance(mr, str):
            if mr.strip().lower() != "auto":
                raise ValueError(f"morsel_rows must be a positive int or 'auto', got {mr!r}")
            self.morsel_rows = "auto"
        elif mr < 1:
            raise ValueError(f"morsel_rows must be >= 1, got {mr}")
        if self.memory_budget < 0:
            raise ValueError(f"memory_budget must be >= 0 (0 = unbounded), got {self.memory_budget}")
        if self.spill_fanout < 2:
            raise ValueError(f"spill_fanout must be >= 2, got {self.spill_fanout}")
        if self.devices is not None:
            devs = tuple(int(d) for d in self.devices)
            if not devs or any(d < 0 for d in devs):
                raise ValueError(f"devices must be a non-empty tuple of indices >= 0, got {self.devices!r}")
            self.devices = devs

    @property
    def auto_morsels(self) -> bool:
        return self.morsel_rows == "auto"

    def initial_morsel_rows(self) -> int:
        return AUTO_MORSEL_INIT if self.auto_morsels else max(1, int(self.morsel_rows))

    def effective_window(self) -> int:
        return self.window if self.window > 0 else 4 * max(1, self.num_workers)


# ---------------------------------------------------------------------------
# adaptive morsel sizing + run stats
# ---------------------------------------------------------------------------
class _MorselSizer:
    """Per-pipeline morsel-size controller.  Workers report each morsel's
    (rows, seconds); an EWMA least-squares fit of the latency model
    ``t(rows) = a + b·rows`` steers the next slice size toward ``target_s``
    per morsel — with a floor that keeps the fixed per-morsel overhead ``a``
    (python dispatch, per-morsel GroupState churn, lock traffic) under
    ~1/(1+_OVERHEAD_K) of each morsel's latency, so a host where overhead
    rivals the 1 ms target (GIL-bound CPUs) doesn't get starved into
    tiny, throughput-losing morsels.  Where overhead is negligible
    (vectorized/TPU compute), the floor vanishes and the controller is a
    pure ~1 ms latency target.  Clamped, in 4096-row steps.  Thread-safe;
    reads are a single attribute load.

    The same latency signal also feeds the pipeline's **reorder window**
    and **prefetch depth** (adaptive mode only): when morsels run at or
    under the latency target the window stays at its configured maximum
    (morsels are cheap — keep every worker busy and the sources read
    ahead); when a morsel costs k× the target, in-flight buffering is
    scaled down by ~1/k toward one morsel per worker, bounding the memory
    held by the reorder buffer and the source queues to a roughly constant
    *time depth* instead of a constant morsel count."""

    _ALPHA = 0.15  # EWMA weight for the regression moments
    _OVERHEAD_K = 8  # morsel must be >= K× the fixed overhead

    def __init__(
        self,
        initial: int,
        adaptive: bool,
        target_s: float = AUTO_TARGET_S,
        lo: int = AUTO_MORSEL_MIN,
        hi: int = AUTO_MORSEL_MAX,
        workers: int = 1,
        window: int = 4,
        prefetch: int = 4,
    ):
        self.size = initial
        self.adaptive = adaptive
        self.target_s = target_s
        self.lo = lo
        self.hi = hi
        self.workers = max(1, workers)
        self.max_window = max(self.workers + 1, window)
        self.max_prefetch = max(1, prefetch)
        self.window = self.max_window
        self.prefetch_depth = self.max_prefetch
        self.morsels = 0
        self.rows = 0
        # fused device-resident pipeline counters (bumped by FusedChainPlan
        # and the micro-morsel coalescer; surfaced via ExecutorStats)
        self.fused_launches = 0
        self.transfers_overlapped = 0
        self.micromorsels_coalesced = 0
        self._m = None  # EWMA moments (E[r], E[t], E[r²], E[r·t])
        self._lock = threading.Lock()

    def current(self) -> int:
        return self.size

    def bump(self, counter: str, k: int = 1) -> None:
        with self._lock:
            setattr(self, counter, getattr(self, counter) + k)

    def observe(self, rows: int, seconds: float) -> None:
        if rows <= 0:
            return
        with self._lock:
            self.morsels += 1
            self.rows += rows
            if not self.adaptive or seconds <= 0.0:
                return
            r, t = float(rows), float(seconds)
            if self._m is None:
                self._m = [r, t, r * r, r * t]
            else:
                al = self._ALPHA
                m = self._m
                m[0] += al * (r - m[0])
                m[1] += al * (t - m[1])
                m[2] += al * (r * r - m[2])
                m[3] += al * (r * t - m[3])
            mr, mt, mrr, mrt = self._m
            var = mrr - mr * mr
            if var > (0.05 * mr) ** 2:  # enough size variety to fit the intercept
                b = (mrt - mr * mt) / var
                a = mt - b * mr
                a = max(a, 0.0)
                b = max(b, mt / mr * 1e-3, 1e-12)
            else:
                a, b = 0.0, mt / mr  # single operating point: pure latency model
            want = max(self.target_s / b, self._OVERHEAD_K * a / b)
            size = int(min(self.hi, max(self.lo, want)))
            self.size = max(self.lo, min(self.hi, size - size % 4096))
            # in-flight scaling from the same signal: fast morsels keep the
            # full window/prefetch; morsels k× over target shrink both ~1/k
            ratio = min(1.0, self.target_s / max(mt, 1e-12))
            lo_w = self.workers + 1
            self.window = lo_w + int(round((self.max_window - lo_w) * ratio))
            self.prefetch_depth = max(1, min(self.max_prefetch, 1 + int(round((self.max_prefetch - 1) * ratio))))


@dataclass
class ExecutorStats:
    """Per-run executor observability.  One entry per pipeline stage drive:
    ``{"morsel_rows": final size, "auto": bool, "morsels": n, "rows": n,
    "window": reorder-window morsels, "prefetch_depth": source read-ahead}``.
    Completed entries land as each stage finishes; stages still driving are
    reported live (``"live": True`` — flow STATUS progress) from their
    attached sizers.  When the run has a memory budget, ``to_dict()``
    additionally carries the shared accountant's ``"spill"`` counters
    (budget, bytes/partitions/batches spilled, grace-hash recursion depth)."""

    pipelines: list = field(default_factory=list)
    accountant: MemoryAccountant | None = None
    live: list = field(default_factory=list)

    @staticmethod
    def _entry(sizer: _MorselSizer) -> dict:
        return {
            "morsel_rows": sizer.size,
            "auto": sizer.adaptive,
            "morsels": sizer.morsels,
            "rows": sizer.rows,
            "window": sizer.window,
            "prefetch_depth": sizer.prefetch_depth,
            "fused_launches": sizer.fused_launches,
            "transfers_overlapped": sizer.transfers_overlapped,
            "micromorsels_coalesced": sizer.micromorsels_coalesced,
        }

    def attach(self, sizer: _MorselSizer) -> None:
        self.live.append(sizer)

    def record(self, sizer: _MorselSizer) -> None:
        try:
            self.live.remove(sizer)
        except ValueError:
            pass
        self.pipelines.append(self._entry(sizer))

    def chosen_morsel_rows(self) -> int | None:
        """The (last pipeline's) tuned morsel size, or None before any
        pipeline completed."""
        return self.pipelines[-1]["morsel_rows"] if self.pipelines else None

    def progress(self) -> dict:
        """Aggregate morsel/row progress across finished + live stages."""
        done = list(self.pipelines)
        running = [self._entry(s) for s in list(self.live)]
        return {
            "morsels_done": sum(p["morsels"] for p in done + running),
            "rows_processed": sum(p["rows"] for p in done + running),
            "stages_done": len(done),
            "stages_running": len(running),
            "fused_launches": sum(p.get("fused_launches", 0) for p in done + running),
            "transfers_overlapped": sum(p.get("transfers_overlapped", 0) for p in done + running),
            "micromorsels_coalesced": sum(p.get("micromorsels_coalesced", 0) for p in done + running),
        }

    def to_dict(self) -> dict:
        d = {"pipelines": list(self.pipelines), **self.progress()}
        if self.accountant is not None:
            d["spill"] = self.accountant.to_dict()
        return d


_last_stats: ExecutorStats | None = None
_last_stats_lock = threading.Lock()


def get_last_stats() -> ExecutorStats | None:
    """Stats of the most recently *created* parallel execution (its entries
    appear as the lazy output is consumed)."""
    with _last_stats_lock:
        return _last_stats


# ---------------------------------------------------------------------------
# bounded source prefetch (network/disk ↔ compute overlap)
# ---------------------------------------------------------------------------
_DONE = object()


class _Prefetch:
    """Pulls an SDF's batches on a background thread into a bounded queue.
    Exceptions (e.g. a dead exchange pull) are re-raised to the consumer
    with their original type, so upstream resilience/retry still works.
    ``depth_fn`` (optional) makes the bound dynamic: the adaptive morsel
    sizer shrinks source read-ahead when batches turn out expensive."""

    def __init__(self, sdf: StreamingDataFrame, depth: int, depth_fn=None):
        self._sdf = sdf
        self._q: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._depth_fn = depth_fn
        self._stop = False
        self._exc: BaseException | None = None
        self._thread: threading.Thread | None = None

    def start(self) -> None:
        if self._thread is None:
            self._thread = threading.Thread(target=self._run, daemon=True)
            self._thread.start()

    def _run(self) -> None:
        try:
            for b in self._sdf.iter_batches():
                if not self._put(b):
                    return
        except BaseException as e:  # noqa: BLE001 - re-raised on the consumer side
            self._exc = e
        self._put(_DONE)

    def _put(self, item) -> bool:
        while not self._stop:
            if self._depth_fn is not None and self._q.qsize() >= self._depth_fn():
                time.sleep(0.01)  # dynamic bound tightened below queue capacity
                continue
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def __iter__(self) -> Iterator[RecordBatch]:
        self.start()
        while not self._stop:
            try:
                item = self._q.get(timeout=0.1)
            except queue.Empty:
                continue
            if item is _DONE:
                if self._exc is not None:
                    raise self._exc
                return
            yield item

    def close(self) -> None:
        self._stop = True


def prefetch_sdf(sdf: StreamingDataFrame, depth: int = 4) -> StreamingDataFrame:
    """Producer-queue wrapper: batches are computed ``depth`` ahead of the
    consumer on a background thread (the server uses this to overlap result
    production with socket writes)."""
    if depth <= 0:
        return sdf

    def gen():
        pf = _Prefetch(sdf, depth)
        try:
            yield from pf
        finally:
            pf.close()

    return StreamingDataFrame(sdf.schema, gen)


# ---------------------------------------------------------------------------
# ordered morsel runs
# ---------------------------------------------------------------------------
class _Branch:
    """One pipeline input: a source SDF plus the op specs applied to its
    morsels.  Unions contribute several branches to the same stage."""

    __slots__ = ("sdf", "specs")

    def __init__(self, sdf: StreamingDataFrame, specs: list | None = None):
        self.sdf = sdf
        self.specs = specs if specs is not None else []


def _apply_ops(cops, batch: RecordBatch) -> RecordBatch | None:
    """Apply a compiled ``(ops, plan)`` chain to one morsel.  A fused plan
    runs the whole chain in one device launch; a morsel outside the kernel
    envelope (nulls, overflow rows) falls back to the per-op closures,
    byte-identically."""
    ops, plan = cops
    if plan is not None:
        out = plan.run(batch)
        if out is not FUSED_INELIGIBLE:
            return out
    for op in ops:
        batch = op(batch)
        if batch is None:
            return None
    return batch


def _morsel_slices(batch: RecordBatch, sizer: _MorselSizer):
    n = batch.num_rows
    if n <= sizer.current():
        yield batch
        return
    s = 0
    while s < n:
        rows = max(1, sizer.current())  # re-read: "auto" retunes mid-batch
        yield batch.slice(s, s + rows)
        s += rows


def _branch_items(cops, batches, sizer: _MorselSizer, cfg: ExecutorConfig, do_stage: bool):
    """One branch's batches → morsels, in input order.

    Adaptive mode coalesces runs of tiny source batches into a single
    morsel (**micro-morsel batching**: when the sizer picks sizes larger
    than what the source produces, launches amortize over the coalesced
    run instead of one per fragment; output order is preserved because
    only *consecutive* batches merge).  On a fused plan, each emitted
    morsel's kernel inputs are staged to the device before the morsel is
    handed to a worker (**double-buffering**: jax H2D transfers are async,
    so morsel N+1's upload overlaps morsel N's compute)."""
    plan = cops[1]
    pending: list = []
    pending_rows = 0

    def emit(m):
        if plan is not None and do_stage:
            plan.stage(m)
        return m

    def flush():
        nonlocal pending, pending_rows
        if not pending:
            return None
        m = pending[0] if len(pending) == 1 else concat_batches(pending)
        if len(pending) > 1:
            sizer.bump("micromorsels_coalesced", len(pending) - 1)
        pending = []
        pending_rows = 0
        return emit(m)

    for batch in batches:
        if cfg.auto_morsels and batch.num_rows < sizer.current():
            if pending and pending_rows + batch.num_rows > sizer.current():
                out = flush()
                if out is not None:
                    yield out
            pending.append(batch)
            pending_rows += batch.num_rows
            continue
        out = flush()
        if out is not None:
            yield out
        for m in _morsel_slices(batch, sizer):
            yield emit(m)
    out = flush()
    if out is not None:
        yield out


_device_rr = itertools.count()  # round-robin cursor over cfg.devices


def _run_ordered(
    branches: list,
    cfg: ExecutorConfig,
    backend: ComputeBackend,
    make_item: Callable,
    stats: ExecutorStats | None = None,
    cancel: threading.Event | None = None,
    agg=None,
):
    """Drive branches' morsels through a worker pool; yield non-None
    ``make_item(cops, morsel)`` results in strict input order.

    With ``num_workers <= 1`` this degrades to a fully synchronous loop —
    no threads, reference pull-chain behavior.

    ``agg`` (``(keys, aggs, mode, in_schema)``) marks an aggregate drive:
    the fused-chain planner then folds the partial aggregate into the same
    per-morsel launch as the streaming ops.

    ``cancel`` is the flow-lifecycle hook: when the event fires, workers
    stop claiming morsels and the driver raises ``FlowCancelled`` instead
    of blocking on upstream, so a CANCELled plan releases its threads,
    prefetchers, and spill files within a bounded delay."""
    compiled = [(br, _finalize_ops(br.specs, backend, br.sdf.schema, agg)) for br in branches]
    sizer = _MorselSizer(
        cfg.initial_morsel_rows(),
        cfg.auto_morsels,
        workers=max(1, cfg.num_workers),
        window=cfg.effective_window(),
        prefetch=cfg.prefetch_batches,
    )
    plans = [cops[1] for _, cops in compiled if cops[1] is not None]
    for pl in plans:
        dev = cfg.devices[next(_device_rr) % len(cfg.devices)] if cfg.devices else None
        pl.bind(sizer, dev)
    if stats is not None:
        stats.attach(sizer)  # live progress (flow STATUS) before the stage ends

    if cfg.num_workers <= 1:
        try:
            for br, cops in compiled:
                for m in _branch_items(cops, br.sdf.iter_batches(), sizer, cfg, do_stage=False):
                    if cancel is not None and cancel.is_set():
                        raise FlowCancelled("execution cancelled")
                    t0 = time.perf_counter()
                    out = make_item(cops, m)
                    sizer.observe(m.num_rows, time.perf_counter() - t0)
                    if out is not None:
                        yield out
        finally:
            for pl in plans:
                pl.clear_staged()
            if stats is not None:
                stats.record(sizer)
        return

    depth_fn = (lambda: sizer.prefetch_depth) if cfg.auto_morsels else None
    prefetchers = [_Prefetch(br.sdf, cfg.prefetch_batches, depth_fn=depth_fn) for br, _ in compiled]
    for pf in prefetchers:
        pf.start()  # all sources (incl. every exchange pull) activate now

    def morsels():
        for (_, cops), pf in zip(compiled, prefetchers):
            for m in _branch_items(cops, pf, sizer, cfg, do_stage=True):
                yield cops, m

    it = morsels()
    src_lock = threading.Lock()
    cond = threading.Condition()
    state = {"assigned": 0, "next": 0, "total": None, "error": None, "stop": False, "buf": {}}

    def worker():
        while True:
            with cond:
                while (
                    not state["stop"]
                    and state["error"] is None
                    and not (cancel is not None and cancel.is_set())
                    and state["assigned"] - state["next"] >= sizer.window
                ):
                    cond.wait(timeout=0.1)
                if state["stop"] or state["error"] is not None or (cancel is not None and cancel.is_set()):
                    return
            with src_lock:
                if state["total"] is not None:
                    return
                try:
                    cops, m = next(it)
                except StopIteration:
                    state["total"] = state["assigned"]
                    with cond:
                        cond.notify_all()
                    return
                except BaseException as e:  # noqa: BLE001 - surfaced to consumer
                    with cond:
                        if state["error"] is None:
                            state["error"] = e
                        state["total"] = state["assigned"]
                        cond.notify_all()
                    return
                seq = state["assigned"]
                state["assigned"] = seq + 1
            try:
                t0 = time.perf_counter()
                out = make_item(cops, m)
                sizer.observe(m.num_rows, time.perf_counter() - t0)
            except BaseException as e:  # noqa: BLE001 - surfaced to consumer
                with cond:
                    if state["error"] is None:
                        state["error"] = e
                    cond.notify_all()
                return
            with cond:
                state["buf"][seq] = out
                cond.notify_all()

    threads = [threading.Thread(target=worker, daemon=True) for _ in range(cfg.num_workers)]
    for t in threads:
        t.start()
    try:
        while True:
            with cond:
                while (
                    state["next"] not in state["buf"]
                    and state["error"] is None
                    and not (cancel is not None and cancel.is_set())
                    and not (state["total"] is not None and state["next"] >= state["total"])
                ):
                    cond.wait(timeout=0.1)
                if cancel is not None and cancel.is_set():
                    raise FlowCancelled("execution cancelled")
                if state["error"] is not None:
                    raise state["error"]
                if state["next"] not in state["buf"]:
                    return  # total reached: all morsels emitted
                item = state["buf"].pop(state["next"])
                state["next"] += 1
                cond.notify_all()
            if item is not None:
                yield item
    finally:
        with cond:
            state["stop"] = True
            cond.notify_all()
        for pf in prefetchers:
            pf.close()
        for pl in plans:
            pl.clear_staged()  # CANCEL/teardown: no leaked staged device buffers
        if stats is not None:
            stats.record(sizer)


# ---------------------------------------------------------------------------
# op-spec finalization (backend binding + filter→select fusion)
# ---------------------------------------------------------------------------
def _finalize_ops(specs: list, backend: ComputeBackend, in_schema: Schema | None = None, agg=None) -> tuple:
    """Turn compile-time op specs into ``(morsel closures, fused plan)``.

    When the whole chain (and, for aggregate drives, the fold) fits the
    fused-pipeline kernel envelope, ``plan`` is a
    :class:`~repro.core.backend.FusedChainPlan` that executes everything in
    ONE device launch per morsel; the per-op closures remain the fallback
    for morsels outside the envelope.  Independently, adjacent
    filter+select pairs are peephole-fused into the backend's two-op
    kernel on the per-op path."""
    plan = plan_fused_chain(specs, in_schema, agg=agg, backend=backend) if in_schema is not None else None
    ops: list = []
    i = 0
    while i < len(specs):
        kind, args = specs[i]
        if kind == "filter" and i + 1 < len(specs) and specs[i + 1][0] == "select":
            pred, cols = args[0], list(specs[i + 1][1][0])
            ops.append(lambda b, _p=pred, _c=cols: backend.filter_select(b, _p, _c))
            i += 2
            continue
        if kind == "filter":
            pred = args[0]
            ops.append(lambda b, _p=pred: filter_morsel(b, _p, backend))
        elif kind == "select":
            cols = list(args[0])
            ops.append(lambda b, _c=cols: select_morsel(b, _c))
        elif kind == "project":
            exprs, out_schema = args
            ops.append(lambda b, _e=exprs, _s=out_schema: backend.project(b, _e, _s))
        elif kind == "map":
            mf, fn_params = args
            ops.append(lambda b, _m=mf, _p=fn_params: map_morsel(b, _m, _p))
        elif kind == "probe":
            once, on, payload, schema = args
            ops.append(
                lambda b, _o=once, _on=on, _pl=payload, _s=schema: join_probe_morsel(
                    b, _o.get()[0], _o.get()[1], _on, _pl, _s
                )
            )
        else:  # pragma: no cover - compiler invariant
            raise PlanError(f"unknown morsel op {kind!r}")
        i += 1
    return ops, plan


class _Once:
    """Thread-safe lazily-computed value (join build table)."""

    def __init__(self, factory: Callable):
        self._factory = factory
        self._lock = threading.Lock()
        self._value = None
        self._ready = False

    def get(self):
        if not self._ready:
            with self._lock:
                if not self._ready:
                    self._value = self._factory()
                    self._ready = True
        return self._value


# ---------------------------------------------------------------------------
# DAG → pipeline compiler
# ---------------------------------------------------------------------------
class _Compiler:
    def __init__(
        self,
        dag: Dag,
        resolver: Callable[[Node], StreamingDataFrame],
        cfg: ExecutorConfig,
        backend: ComputeBackend,
        stats: ExecutorStats | None = None,
        acct: MemoryAccountant | None = None,
        cancel=None,
    ):
        self.dag = dag
        self.resolver = resolver
        self.cfg = cfg
        self.backend = backend
        self.stats = stats
        self.cancel = cancel  # flow-lifecycle cancellation event (or None)
        # one accountant per run, shared by every breaker in the plan
        self.acct = acct if acct is not None else MemoryAccountant(cfg.memory_budget)
        self._memo: dict = {}  # node id -> (branches, schema)

    def compile(self) -> StreamingDataFrame:
        branches, schema = self._stream(self.dag.output)
        return self._stage_sdf(branches, schema)

    # -- stage assembly -----------------------------------------------------
    def _stage_sdf(self, branches: list, schema: Schema) -> StreamingDataFrame:
        if len(branches) == 1 and not branches[0].specs:
            return branches[0].sdf  # nothing to compute: pass the source through

        def gen():
            yield from _run_ordered(branches, self.cfg, self.backend, _apply_ops, self.stats, self.cancel)

        return StreamingDataFrame(schema, gen)

    def _collect_stage(self, branches: list, schema: Schema) -> RecordBatch:
        got = list(_run_ordered(branches, self.cfg, self.backend, _apply_ops, self.stats, self.cancel))
        return concat_batches(got) if got else RecordBatch.empty(schema)

    # -- recursive compilation ---------------------------------------------
    def _stream(self, nid: str) -> tuple:
        memo = self._memo.get(nid)
        if memo is not None:
            branches, schema = memo
            # consumers mutate spec lists; hand each its own copy
            return [_Branch(br.sdf, list(br.specs)) for br in branches], schema
        out = self._compile_node(self.dag.nodes[nid])
        self._memo[nid] = out
        branches, schema = out
        return [_Branch(br.sdf, list(br.specs)) for br in branches], schema

    def _compile_node(self, node: Node) -> tuple:
        op = node.op
        if op in ("source", "exchange"):
            sdf = self.resolver(node)
            return [_Branch(sdf)], sdf.schema
        if op in _STREAMING_OPS:
            branches, schema = self._stream(node.inputs[0])
            spec, schema = self._streaming_spec(node, schema)
            for br in branches:
                br.specs.append(spec)
            return branches, schema
        if op == "union":
            branches, schema = self._stream(node.inputs[0])
            for other in node.inputs[1:]:
                b2, s2 = self._stream(other)
                if not s2.equals(schema):
                    raise SchemaError("union over mismatched schemas")
                branches.extend(b2)
            return branches, schema
        if op == "aggregate":
            return self._compile_aggregate(node)
        if op == "join":
            return self._compile_join(node)
        if op in ("limit", "rebatch"):
            # sequential-by-nature: serial tail over the parallel upstream
            branches, schema = self._stream(node.inputs[0])
            sdf = execute_node(node, [self._stage_sdf(branches, schema)])
            return [_Branch(sdf)], sdf.schema
        raise PlanError(f"operator {op!r} has no parallel evaluator")

    def _streaming_spec(self, node: Node, in_schema: Schema) -> tuple:
        if node.op == "filter":
            return ("filter", (node.params["predicate"],)), in_schema
        if node.op == "select":
            cols = list(node.params["columns"])
            return ("select", (cols,)), in_schema.select(cols)
        if node.op == "project":
            exprs = dict(node.params["exprs"])
            keep = bool(node.params.get("keep", True))
            out_schema = project_schema(in_schema, exprs, keep)
            return ("project", (exprs, out_schema)), out_schema
        if node.op == "map":
            mf = get_map(node.params["fn"])
            fn_params = dict(node.params.get("fn_params", {}))
            return ("map", (mf, fn_params)), mf.schema_fn(in_schema, **fn_params)
        raise PlanError(f"not a streaming op: {node.op!r}")  # pragma: no cover

    def _compile_aggregate(self, node: Node) -> tuple:
        keys = list(node.params["keys"])
        aggs = dict(node.params["aggs"])
        mode = node.params.get("mode", "full")
        branches, in_schema = self._stream(node.inputs[0])
        missing = [k for k in keys if k not in in_schema]
        if missing:
            raise SchemaError(f"aggregate keys missing from input: {missing}")
        out_schema = Schema(agg_out_fields(in_schema, keys, aggs, mode))
        cfg, backend, stats, acct, cancel = self.cfg, self.backend, self.stats, self.acct, self.cancel
        spillable = acct.enabled and GraceHashAggregate.supported(keys, aggs, mode, in_schema)
        if acct.enabled and keys and not spillable:
            # a keyless aggregate is a single bounded group — but a name
            # collision with the reserved spill columns means this breaker
            # runs UNBOUNDED despite the budget; never silently
            warnings.warn(
                f"aggregate on keys {keys} cannot grace-hash spill (reserved spill-column "
                f"name collision); its state is NOT memory-budgeted",
                stacklevel=2,
            )

        def fold(cops, morsel):
            ops, plan = cops
            if plan is not None:
                # fused device-resident fold: filter → project → compact →
                # segment fold in ONE launch, GroupState materialized from
                # the kernel's per-group accumulators (byte-identical)
                st = plan.fold(morsel)
                if st is not FUSED_INELIGIBLE:
                    return st
            b = _apply_ops((ops, None), morsel)
            if b is None or b.num_rows == 0:
                return None
            # backend-aware fold: eligible aggregates run on the
            # segment-reduce kernel once keys are factorized (pushdown R9
            # partials on the accelerator)
            st = GroupState(keys, aggs, mode, in_schema, vectorized=True, backend=backend)
            st.update(b)
            return st

        def agg_gen():
            # breaker: fold morsels into per-morsel partial states in
            # parallel, merge them in morsel order (deterministic output).
            # Under a memory budget the merged state's accounted bytes are
            # tracked; crossing the budget switches to grace-hash spill —
            # the partial states (prefix first, then per-morsel) scatter to
            # disk by key hash and re-merge per partition, byte-identically.
            total = GroupState(keys, aggs, mode, in_schema, vectorized=True)
            spiller = None
            reserved = 0
            try:
                for st in _run_ordered(branches, cfg, backend, fold, stats, cancel, agg=(keys, aggs, mode, in_schema)):
                    if spiller is not None:
                        spiller.spill_state(st)
                        continue
                    total.merge(st)
                    if spillable:
                        nb = total.approx_nbytes()
                        acct.adjust(nb - reserved)
                        reserved = nb
                        if acct.over():
                            spiller = GraceHashAggregate(
                                keys,
                                aggs,
                                mode,
                                in_schema,
                                out_schema,
                                acct,
                                backend=backend,
                                morsel_rows=cfg.initial_morsel_rows(),
                                fanout=cfg.spill_fanout,
                                spill_dir=cfg.spill_dir,
                            )
                            spiller.spill_state(total)
                            total = None
                            acct.adjust(-reserved)
                            reserved = 0
                if spiller is None:
                    yield total.result(out_schema)
                else:
                    yield spiller.result()
            finally:
                acct.adjust(-reserved)
                if spiller is not None:
                    spiller.close()

        return [_Branch(StreamingDataFrame(out_schema, agg_gen))], out_schema

    def _compile_join(self, node: Node) -> tuple:
        on = list(node.params["on"])
        left_branches, ls = self._stream(node.inputs[0])
        right_branches, rs = self._stream(node.inputs[1])
        schema, payload, _rename = join_schema(ls, rs, on)

        if self.acct.enabled:
            if ROWID_COL not in ls:
                return self._compile_join_budgeted(left_branches, ls, right_branches, rs, on, payload, schema)
            warnings.warn(
                f"join probe schema contains the reserved column {ROWID_COL!r}; "
                f"its build side is NOT memory-budgeted",
                stacklevel=2,
            )

        def build():
            rb = self._collect_stage(right_branches, rs)
            return rb, build_join_table(rb, on)

        once = _Once(build)
        for br in left_branches:
            br.specs.append(("probe", (once, on, payload, schema)))
        return left_branches, schema

    def _compile_join_budgeted(self, left_branches, ls, right_branches, rs, on, payload, schema) -> tuple:
        """Memory-budgeted join: the build side collects under the shared
        accountant and grace-hash spills past the budget.  When the build
        fits, probing stays **morsel-parallel** — a probe-spec stage over
        the left stage's output (one extra stage hop vs the unbudgeted
        fused path, the price of not knowing spill-vs-mem until the build
        runs; left sources may be one-shot exchange pulls, so the decision
        cannot be retried).  Only a spilled build degrades to the serial
        partition-paired drive.  Collected results are byte-identical to
        the fused in-memory probe either way."""
        cfg, backend, stats, acct, cancel = self.cfg, self.backend, self.stats, self.acct, self.cancel

        def build():
            batches = _run_ordered(right_branches, cfg, backend, _apply_ops, stats, cancel)
            return collect_build(
                batches,
                rs,
                on,
                acct,
                fanout=cfg.spill_fanout,
                spill_dir=cfg.spill_dir,
            )

        once = _Once(build)

        class _MemTable:
            """probe-spec adapter: .get() -> (build batch, table)."""

            def get(self):
                res = once.get()
                assert res[0] == "mem"  # only consulted on the in-memory path
                return res[1], res[2]

        left_sdf = self._stage_sdf(left_branches, ls)

        def join_gen():
            res = once.get()
            if res[0] == "mem":
                probe_branches = [_Branch(left_sdf, [("probe", (_MemTable(), on, payload, schema))])]
                yield from _run_ordered(probe_branches, cfg, backend, _apply_ops, stats, cancel)
            else:
                yield from spilled_join_stream(
                    res[1],
                    left_sdf.iter_batches(),
                    on,
                    payload,
                    schema,
                    ls,
                    acct,
                    morsel_rows=cfg.initial_morsel_rows(),
                    fanout=cfg.spill_fanout,
                    spill_dir=cfg.spill_dir,
                )

        return [_Branch(StreamingDataFrame(schema, join_gen))], schema


def execute_parallel(
    dag: Dag,
    source_resolver: Callable[[Node], StreamingDataFrame],
    config: ExecutorConfig | None = None,
    stats: ExecutorStats | None = None,
    cancel=None,
) -> StreamingDataFrame:
    """Wire the DAG into morsel-parallel pipelines and return the output SDF.

    Semantics match ``operators.execute`` (same rows, same order for a given
    morsel size); execution is lazy — workers start on the first pull.
    ``stats`` (or ``get_last_stats()``) collects per-pipeline morsel counts
    and the tuned morsel size as the output is consumed.  ``cancel`` (a
    ``threading.Event``) is the flow-lifecycle cancellation hook: setting it
    makes every stage raise ``FlowCancelled`` and release its workers,
    prefetchers, and spill state within a bounded delay."""
    global _last_stats
    cfg = config or ExecutorConfig()
    backend = get_backend(cfg.backend)
    if stats is None:
        stats = ExecutorStats()
    acct = MemoryAccountant(cfg.memory_budget)
    stats.accountant = acct
    with _last_stats_lock:
        _last_stats = stats
    return _Compiler(dag, source_resolver, cfg, backend, stats, acct, cancel).compile()
