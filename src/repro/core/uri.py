"""dacp:// unified resource addressing (paper §III-C, eq. 3).

    dacp://<host>:<port>/[<dataset_name>]/<path>

``dataset_name`` is optional — whether the first segment names a dataset is
resolved against the server catalog, so the parsed form keeps raw segments
and exposes both interpretations.
"""

from __future__ import annotations

import re
from dataclasses import dataclass

from repro.core.errors import ResourceNotFound

__all__ = ["DacpUri", "parse", "format_uri"]

_URI_RE = re.compile(
    r"^dacp://(?P<host>\[[0-9a-fA-F:]+\]|[^:/\s]+)(?::(?P<port>\d{1,5}))?(?P<path>/.*)?$"
)

DEFAULT_PORT = 3101


@dataclass(frozen=True)
class DacpUri:
    host: str
    port: int
    segments: tuple  # path split on '/', no empties

    @property
    def path(self) -> str:
        return "/" + "/".join(self.segments)

    @property
    def dataset_candidate(self) -> str | None:
        return self.segments[0] if self.segments else None

    @property
    def subpath(self) -> str:
        return "/".join(self.segments[1:])

    def child(self, *more: str) -> "DacpUri":
        extra = []
        for m in more:
            extra.extend(s for s in m.split("/") if s)
        return DacpUri(self.host, self.port, self.segments + tuple(extra))

    @property
    def authority(self) -> str:
        return f"{self.host}:{self.port}"

    def __str__(self) -> str:
        return f"dacp://{self.host}:{self.port}{self.path}"


def parse(uri: str) -> DacpUri:
    m = _URI_RE.match(uri.strip())
    if not m:
        raise ResourceNotFound(f"not a dacp:// URI: {uri!r}")
    host = m.group("host")
    port = int(m.group("port") or DEFAULT_PORT)
    if not (0 < port < 65536):
        raise ResourceNotFound(f"bad port in {uri!r}")
    raw = m.group("path") or "/"
    segments = tuple(s for s in raw.split("/") if s)
    return DacpUri(host=host, port=port, segments=segments)


def format_uri(host: str, port: int, *segments: str) -> str:
    segs = []
    for s in segments:
        segs.extend(x for x in str(s).split("/") if x)
    return str(DacpUri(host, port, tuple(segs)))
