"""StreamingDataFrame D = <S, F>  (paper §III-A, eq. 1).

An SDF is a Schema plus an ordered stream of RecordBatches.  It exposes
``Iterator<Row>`` logical semantics while moving data in columnar batches.
Computation downstream of an SDF starts as soon as beta_0 arrives — nothing
here ever waits for the full stream (lazy/streaming by construction).

The batch stream is produced by a zero-argument factory so an SDF can be
re-iterated (fresh generator per consumer) when the underlying source allows
it; one-shot network streams simply raise on the second iteration.
"""

from __future__ import annotations

from typing import Callable, Iterable, Iterator

from repro.core.batch import RecordBatch, concat_batches
from repro.core.errors import SchemaError
from repro.core.schema import Schema

__all__ = ["StreamingDataFrame", "SDF"]


class StreamingDataFrame:
    __slots__ = ("schema", "_factory", "__weakref__")

    def __init__(self, schema: Schema, batch_factory: Callable[[], Iterator[RecordBatch]]):
        self.schema = schema
        self._factory = batch_factory

    # -- constructors -----------------------------------------------------------
    @staticmethod
    def from_batches(batches: Iterable[RecordBatch], schema: Schema | None = None) -> "StreamingDataFrame":
        batches = list(batches)
        if schema is None:
            if not batches:
                raise SchemaError("cannot infer schema from zero batches")
            schema = batches[0].schema
        for b in batches:
            if not b.schema.equals(schema):
                raise SchemaError("inconsistent batch schema in SDF")
        return StreamingDataFrame(schema, lambda: iter(batches))

    @staticmethod
    def from_pydict(data: dict, schema: Schema | None = None, batch_rows: int = 65536) -> "StreamingDataFrame":
        full = RecordBatch.from_pydict(data, schema)

        def gen():
            for s in range(0, max(full.num_rows, 1), batch_rows):
                yield full.slice(s, s + batch_rows)

        return StreamingDataFrame(full.schema, gen)

    @staticmethod
    def from_generator(schema: Schema, gen_factory: Callable[[], Iterator[RecordBatch]]) -> "StreamingDataFrame":
        return StreamingDataFrame(schema, gen_factory)

    @staticmethod
    def one_shot(schema: Schema, iterator: Iterator[RecordBatch]) -> "StreamingDataFrame":
        state = {"used": False}

        def gen():
            if state["used"]:
                raise SchemaError("one-shot SDF stream already consumed")
            state["used"] = True
            return iterator

        return StreamingDataFrame(schema, gen)

    # -- transformation -------------------------------------------------------
    def map_batches(
        self, fn: Callable[[RecordBatch], RecordBatch], schema: Schema | None = None
    ) -> "StreamingDataFrame":
        """Lazily apply ``fn`` to every batch (executor/engine glue — e.g.
        per-batch accounting or casting).  ``schema`` overrides the output
        schema when ``fn`` changes it; defaults to the input schema."""
        out_schema = schema if schema is not None else self.schema

        def gen() -> Iterator[RecordBatch]:
            for b in self.iter_batches():
                yield fn(b)

        return StreamingDataFrame(out_schema, gen)

    # -- consumption ----------------------------------------------------------
    def iter_batches(self) -> Iterator[RecordBatch]:
        return iter(self._factory())

    def __iter__(self) -> Iterator[dict]:
        return self.iter_rows()

    def iter_rows(self) -> Iterator[dict]:
        """Iterator<Row> view (paper: logical rows, physical batches)."""
        for batch in self.iter_batches():
            yield from batch.iter_rows()

    def collect(self) -> RecordBatch:
        batches = list(self.iter_batches())
        if not batches:
            return RecordBatch.empty(self.schema)
        return concat_batches(batches)

    def head(self, n: int = 10) -> RecordBatch:
        got, rows = [], 0
        for b in self.iter_batches():
            need = n - rows
            if b.num_rows > need:
                b = b.slice(0, need)
            got.append(b)
            rows += b.num_rows
            if rows >= n:
                break
        if not got:
            return RecordBatch.empty(self.schema)
        return concat_batches(got)

    def count_rows(self) -> int:
        return sum(b.num_rows for b in self.iter_batches())


SDF = StreamingDataFrame
