"""Serializable predicate / projection expressions.

These are the vertices' payloads for Filter/Map operators in a COOK DAG
(paper §III-B).  Expressions are a small closed algebra — column refs,
literals, comparisons, arithmetic, boolean connectives, string ops — so that
a server can (a) evaluate them vectorized over columnar batches and
(b) reason about them for predicate pushdown (referenced_columns).

They are wire-serializable as JSON and never carry executable code: COOK
payloads are *data*, which is what makes cross-domain offload safe.
"""

from __future__ import annotations

import numpy as np

from repro.core.batch import Column, RecordBatch
from repro.core.errors import PlanError, TypeMismatchError

__all__ = ["Expr", "col", "lit", "and_", "or_", "not_"]

_CMP = {
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
    "lt": lambda a, b: a < b,
    "le": lambda a, b: a <= b,
    "gt": lambda a, b: a > b,
    "ge": lambda a, b: a >= b,
}
_ARITH = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "div": lambda a, b: a / b,
    "mod": lambda a, b: a % b,
}
_BOOL = {"and": np.logical_and, "or": np.logical_or}


class Expr:
    """Expression node: op + children/args, JSON-serializable."""

    __slots__ = ("op", "args")

    def __init__(self, op: str, args: tuple):
        self.op = op
        self.args = args

    # -- builders (chainable sugar) -----------------------------------------
    def _bin(self, op, other) -> "Expr":
        return Expr(op, (self, _wrap(other)))

    def __eq__(self, o):  # type: ignore[override]
        return self._bin("eq", o)

    def __ne__(self, o):  # type: ignore[override]
        return self._bin("ne", o)

    def __lt__(self, o):
        return self._bin("lt", o)

    def __le__(self, o):
        return self._bin("le", o)

    def __gt__(self, o):
        return self._bin("gt", o)

    def __ge__(self, o):
        return self._bin("ge", o)

    def __add__(self, o):
        return self._bin("add", o)

    def __sub__(self, o):
        return self._bin("sub", o)

    def __mul__(self, o):
        return self._bin("mul", o)

    def __truediv__(self, o):
        return self._bin("div", o)

    def __mod__(self, o):
        return self._bin("mod", o)

    def __and__(self, o):
        return self._bin("and", o)

    def __or__(self, o):
        return self._bin("or", o)

    def __invert__(self):
        return Expr("not", (self,))

    def isin(self, values) -> "Expr":
        return Expr("isin", (self, tuple(values)))

    def contains(self, needle: str) -> "Expr":
        return Expr("contains", (self, needle))

    def startswith(self, prefix: str) -> "Expr":
        return Expr("startswith", (self, prefix))

    def length(self) -> "Expr":
        return Expr("length", (self,))

    def __hash__(self):
        return hash((self.op, str(self.args)))

    # -- analysis -------------------------------------------------------------
    def referenced_columns(self) -> set:
        out = set()
        stack = [self]
        while stack:
            e = stack.pop()
            if not isinstance(e, Expr):
                continue
            if e.op == "col":
                out.add(e.args[0])
            else:
                stack.extend(a for a in e.args if isinstance(a, Expr))
        return out

    # -- evaluation (vectorized over a RecordBatch) ----------------------------
    def evaluate(self, batch: RecordBatch) -> np.ndarray:
        return _eval(self, batch)

    # -- wire -------------------------------------------------------------------
    def to_json(self):
        def enc(a):
            if isinstance(a, Expr):
                return a.to_json()
            if isinstance(a, tuple):
                return {"$tuple": [enc(x) for x in a]}
            if isinstance(a, (bytes, bytearray)):
                return {"$bytes": bytes(a).hex()}
            return a

        return {"$op": self.op, "args": [enc(a) for a in self.args]}

    @staticmethod
    def from_json(d) -> "Expr":
        def dec(a):
            if isinstance(a, dict) and "$op" in a:
                return Expr.from_json(a)
            if isinstance(a, dict) and "$tuple" in a:
                return tuple(dec(x) for x in a["$tuple"])
            if isinstance(a, dict) and "$bytes" in a:
                return bytes.fromhex(a["$bytes"])
            return a

        if not (isinstance(d, dict) and "$op" in d):
            raise PlanError(f"malformed expression payload: {d!r}")
        return Expr(d["$op"], tuple(dec(a) for a in d["args"]))

    def __repr__(self):  # pragma: no cover - cosmetic
        if self.op == "col":
            return f"col({self.args[0]!r})"
        if self.op == "lit":
            return repr(self.args[0])
        return f"{self.op}({', '.join(map(repr, self.args))})"


def col(name: str) -> Expr:
    return Expr("col", (name,))


def lit(v) -> Expr:
    return Expr("lit", (v,))


def and_(*exprs: Expr) -> Expr:
    out = exprs[0]
    for e in exprs[1:]:
        out = out & e
    return out


def or_(*exprs: Expr) -> Expr:
    out = exprs[0]
    for e in exprs[1:]:
        out = out | e
    return out


def not_(e: Expr) -> Expr:
    return ~e


def _wrap(v) -> Expr:
    return v if isinstance(v, Expr) else lit(v)


def _as_comparable(colobj: Column):
    """Var-width columns compare as python object arrays (strings)."""
    if colobj.dtype.is_varwidth:
        return np.asarray(colobj.to_pylist(), dtype=object)
    return colobj.values


def _eval(e: Expr, batch: RecordBatch):
    op = e.op
    if op == "col":
        return _as_comparable(batch.column(e.args[0]))
    if op == "lit":
        return e.args[0]
    if op in _CMP:
        a, b = _eval(e.args[0], batch), _eval(e.args[1], batch)
        return np.asarray(_CMP[op](a, b), dtype=bool)
    if op in _ARITH:
        a, b = _eval(e.args[0], batch), _eval(e.args[1], batch)
        return _ARITH[op](a, b)
    if op in _BOOL:
        a, b = _eval(e.args[0], batch), _eval(e.args[1], batch)
        return _BOOL[op](np.asarray(a, bool), np.asarray(b, bool))
    if op == "not":
        return np.logical_not(np.asarray(_eval(e.args[0], batch), bool))
    if op == "isin":
        a = _eval(e.args[0], batch)
        vals = set(e.args[1])
        return np.asarray([x in vals for x in np.asarray(a).tolist()], dtype=bool)
    if op == "contains":
        a = _eval(e.args[0], batch)
        needle = e.args[1]
        return np.asarray([needle in (x or "") for x in a.tolist()], dtype=bool)
    if op == "startswith":
        a = _eval(e.args[0], batch)
        pre = e.args[1]
        return np.asarray([(x or "").startswith(pre) for x in a.tolist()], dtype=bool)
    if op == "length":
        a = e.args[0]
        if isinstance(a, Expr) and a.op == "col":
            c = batch.column(a.args[0])
            if c.dtype.is_varwidth:
                return (c.offsets[1:] - c.offsets[:-1]).astype(np.int64)
        return np.asarray([len(x) for x in np.asarray(_eval(a, batch)).tolist()], np.int64)
    raise TypeMismatchError(f"unknown expression op {op!r}")
