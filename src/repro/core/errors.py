"""DACP protocol error hierarchy.

Errors carry a wire-serializable ``code`` so servers can frame them back to
clients without losing the category (paper §III-C: phased interaction must
surface auth/addressing failures distinctly from execution failures).
"""

from __future__ import annotations


class DacpError(Exception):
    """Base class for every protocol-level error."""

    code = "DACP_ERROR"

    def to_wire(self) -> dict:
        return {"code": self.code, "message": str(self)}

    @staticmethod
    def from_wire(payload: dict) -> "DacpError":
        code = payload.get("code", "DACP_ERROR")
        msg = payload.get("message", "")
        cls = _CODE_TO_CLS.get(code, DacpError)
        return cls(msg)


class SchemaError(DacpError):
    """Schema mismatch / malformed schema."""

    code = "SCHEMA"


class TypeMismatchError(SchemaError):
    code = "TYPE_MISMATCH"


class ResourceNotFound(DacpError):
    """URI did not resolve to a dataset / SDF."""

    code = "NOT_FOUND"


class PermissionDenied(DacpError):
    code = "PERMISSION"


class TokenError(PermissionDenied):
    """Missing / expired / forged access token."""

    code = "TOKEN"


class PlanError(DacpError):
    """Malformed or unschedulable COOK DAG."""

    code = "PLAN"


class TransportError(DacpError):
    """Framing / channel-level failure."""

    code = "TRANSPORT"


class SubTaskFailed(DacpError):
    """A physical sub-task exhausted its retries."""

    code = "SUBTASK"


class FlowCancelled(DacpError):
    """A flow was cancelled (client CANCEL verb or server-side teardown).

    Raised by executor pipelines when their flow's cancel event fires, and
    framed to consumers of a cancelled stream.  Clients must treat it as
    terminal — unlike ``TransportError`` it is never retried/resumed."""

    code = "FLOW_CANCELLED"


_CODE_TO_CLS = {
    c.code: c
    for c in (
        DacpError,
        SchemaError,
        TypeMismatchError,
        ResourceNotFound,
        PermissionDenied,
        TokenError,
        PlanError,
        TransportError,
        SubTaskFailed,
        FlowCancelled,
    )
}
