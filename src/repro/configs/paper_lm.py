"""paper-lm-100m — the end-to-end training example's own model.

A ~100M decoder-only LM fed by the DACP data plane (examples/train_lm.py):
byte-level vocab, 12L × 768.  This is the paper's "AI4Science joint
training" consumer in minimal runnable form.
"""

from repro.configs.base import ArchConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="paper-lm-100m",
        family="dense",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab_size=512,  # byte tokenizer (259) padded
        vocab_pad_multiple=64,
        act="silu",
        glu=True,
        norm="rmsnorm",
        tie_embeddings=True,
        dtype="float32",
        param_dtype="float32",
        remat=False,
        source="in-repo",
    )
)
