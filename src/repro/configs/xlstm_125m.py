"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

12L d_model=768 4H d_ff=0 vocab=50304.  ``d_ff=0`` → no separate FFN: the
up/down projections live inside the xLSTM blocks (mLSTM pf=2, sLSTM with
GLU ffn pf=4/3 per the paper).  One sLSTM per 8 blocks (7:1 ratio).
Recurrent state → runs ``long_500k``.
"""

from repro.configs.base import ArchConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="xlstm-125m",
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        act="gelu",
        glu=False,
        norm="layernorm",
        block_pattern="xlstm",
        slstm_every=8,
        tie_embeddings=True,
        source="arXiv:2405.04517; unverified",
    )
)
