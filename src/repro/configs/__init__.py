"""Assigned architectures (+ the paper example LM) as selectable configs."""

from repro.configs.base import (
    SHAPES,
    ArchConfig,
    MoECfg,
    ShapeSpec,
    SSMCfg,
    get_config,
    list_archs,
    register_arch,
)

__all__ = [
    "SHAPES",
    "ArchConfig",
    "MoECfg",
    "ShapeSpec",
    "SSMCfg",
    "get_config",
    "list_archs",
    "register_arch",
]
