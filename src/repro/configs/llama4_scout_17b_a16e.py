"""llama4-scout-17b-a16e — MoE, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts top-1.
Assignment-literal top-1 routing (HF adds a shared expert; noted in
DESIGN.md §4).  Full attention → ``long_500k`` skipped.
"""

from repro.configs.base import ArchConfig, MoECfg, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        act="silu",
        glu=True,
        norm="rmsnorm",
        tie_embeddings=False,
        moe=MoECfg(n_experts=16, top_k=1, d_ff_expert=8192, n_shared_experts=0),
        source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
    )
)
