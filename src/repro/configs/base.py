"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; the four assigned
input shapes are ``ShapeSpec``s.  ``reduced()`` derives the CPU smoke-test
version of any config (same family/topology, tiny dims).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

__all__ = ["MoECfg", "SSMCfg", "ArchConfig", "ShapeSpec", "SHAPES", "register_arch", "get_config", "list_archs"]


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_every: int = 1  # every Nth block is MoE (1 = all)
    group_size: int = 512  # einsum-dispatch token group (GShard G×g regroup)


@dataclass(frozen=True)
class SSMCfg:
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    conv_kernel: int = 4
    chunk: int = 256  # SSD chunk length


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // n_heads
    act: str = "silu"  # silu | gelu
    glu: bool = True  # gated (SwiGLU/GeGLU) vs plain MLP
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    qkv_bias: bool = False
    mlp_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    partial_rotary: float = 1.0  # fraction of head_dim that rotates
    pos_emb: str = "rope"  # rope | learned | none
    tie_embeddings: bool = True
    vocab_pad_multiple: int = 256
    # MoE
    moe: MoECfg | None = None
    # hybrid / ssm topology
    block_pattern: str = "attn"  # attn | zamba2 | xlstm
    ssm: SSMCfg | None = None
    attn_every: int = 6  # zamba2: shared attn after every Nth mamba block
    slstm_every: int = 8  # xlstm: one sLSTM per N blocks
    # encoder-decoder
    is_encdec: bool = False
    encoder_layers: int = 0
    enc_seq: int = 1500  # whisper: frames after the conv stem (stubbed)
    # modality frontend stub
    frontend: str = "none"  # none | audio_stub | vq_stub
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"  # full | dots (checkpoint_dots: save matmul outs)
    loss_impl: str = "logp"  # logp (materialize log_softmax) | lse (logsumexp-gather)
    moe_dispatch: str = "scatter"  # scatter | einsum (one-hot matmul dispatch)
    attn_impl: str = "auto"  # auto | naive | chunked
    zero3_gather: bool = False  # explicit ZeRO-3: all-gather FSDP weights at
    # use (with_sharding_constraint → replicated) instead of letting GSPMD
    # partial-sum activations and all-reduce them (§Perf hillclimb)
    max_seq: int = 532480
    source: str = ""  # provenance tag from the assignment

    # -- derived -------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def n_params(self) -> int:
        """Total parameter count (embedding included once if tied)."""
        d, hd = self.d_model, self.head_dim_
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        if self.glu:
            mlp_dense = 3 * d * self.d_ff
        else:
            mlp_dense = 2 * d * self.d_ff
        per_layer = 0
        n_attn_layers = self.n_layers if self.block_pattern == "attn" else 0
        if self.block_pattern == "attn":
            if self.moe is not None:
                moe_mlp = self.moe.n_experts * 3 * d * self.moe.d_ff_expert
                moe_mlp += self.moe.n_shared_experts * 3 * d * self.moe.d_ff_expert
                moe_mlp += d * self.moe.n_experts  # router
                n_moe = self.n_layers // self.moe.moe_every
                n_dense = self.n_layers - n_moe
                per_layer_total = n_moe * (attn + moe_mlp) + n_dense * (attn + mlp_dense)
            else:
                per_layer_total = self.n_layers * (attn + mlp_dense)
        elif self.block_pattern == "zamba2":
            # mamba blocks have NO per-layer MLP; one shared attn+MLP block
            s = self.ssm or SSMCfg()
            d_in = s.expand * d
            nh = d_in // s.head_dim
            mamba = (
                d * (2 * d_in + 2 * s.d_state + nh)  # z,x,B,C,dt projections
                + d_in * d  # out proj
                + s.conv_kernel * (d_in + 2 * s.d_state)  # depthwise convs
                + d_in  # gate norm
            )
            per_layer_total = self.n_layers * mamba + (attn + mlp_dense)
        elif self.block_pattern == "xlstm":
            pf = 2
            d_in = pf * d
            # mLSTM block: up+gate (2·d·d_in), q/k/v (3·d_in²), i/f gates,
            # down (d_in·d); one sLSTM block per slstm_every with block-diag
            # recurrence + a 4/3-GLU FFN
            mlstm = 2 * d * d_in + 3 * d_in * d_in + d_in * 2 * self.n_heads + d_in * d
            hd = d // self.n_heads
            d_ff_s = int(d * 4 / 3)
            slstm = 4 * d * d + 3 * self.n_heads * hd * hd + 3 * d * d_ff_s
            n_s = self.n_layers // self.slstm_every
            per_layer_total = (self.n_layers - n_s) * mlstm + n_s * slstm
        else:
            per_layer_total = self.n_layers * (attn + mlp_dense)
        emb = self.padded_vocab * d
        if not self.tie_embeddings:
            emb *= 2
        if self.is_encdec:
            enc = self.encoder_layers * (attn + mlp_dense)
            dec_cross = self.n_layers * attn  # cross-attention blocks
            per_layer_total += enc + dec_cross
        _ = n_attn_layers
        return int(per_layer_total + emb)

    def active_params(self) -> int:
        """MoE: parameters touched per token (top-k + shared experts)."""
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        dense_like = dataclasses.replace(self, moe=None)
        base = dense_like.n_params() - self.n_layers * 3 * d * self.d_ff
        n_moe = self.n_layers // self.moe.moe_every
        n_dense = self.n_layers - n_moe
        active_moe = n_moe * (self.moe.top_k + self.moe.n_shared_experts) * 3 * d * self.moe.d_ff_expert
        return int(base + n_dense * 3 * d * self.d_ff + active_moe)

    def reduced(self) -> "ArchConfig":
        """Tiny same-topology config for CPU smoke tests."""
        changes = dict(
            n_layers=min(self.n_layers, 4 if self.block_pattern == "attn" else 5),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // max(self.n_heads, 1))),
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            vocab_pad_multiple=64,
            dtype="float32",
            param_dtype="float32",
            remat=False,
            max_seq=4096,
        )
        if self.moe is not None:
            # capacity_factor=4 ⇒ no token drops at smoke scale, so
            # decode-vs-forward agreement is exact (production keeps 1.25)
            changes["moe"] = MoECfg(
                n_experts=4,
                top_k=min(2, self.moe.top_k),
                d_ff_expert=64,
                n_shared_experts=self.moe.n_shared_experts,
                capacity_factor=4.0,
                moe_every=self.moe.moe_every,
            )
        if self.ssm is not None:
            changes["ssm"] = SSMCfg(d_state=16, expand=2, head_dim=32, conv_kernel=4, chunk=32)
        if self.is_encdec:
            changes["encoder_layers"] = 2
            changes["enc_seq"] = 16
        if self.block_pattern == "zamba2":
            changes["attn_every"] = 2
            changes["n_layers"] = 5
        if self.block_pattern == "xlstm":
            changes["slstm_every"] = 3
            changes["n_layers"] = 4
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

_REGISTRY: dict = {}


def register_arch(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}") from None


def list_archs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    import importlib

    for mod in (
        "chameleon_34b",
        "moonshot_v1_16b_a3b",
        "llama4_scout_17b_a16e",
        "whisper_small",
        "gemma_2b",
        "stablelm_1_6b",
        "granite_3_8b",
        "qwen1_5_0_5b",
        "zamba2_1_2b",
        "xlstm_125m",
        "paper_lm",
    ):
        importlib.import_module(f"repro.configs.{mod}")
