"""granite-3-8b — dense GQA [hf:ibm-granite/granite-3.0-2b-base; hf].

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.  The odd vocab
(49155) is padded to the next multiple of 256 for TP divisibility
(logits masked; DESIGN.md §5).  Full attention → ``long_500k`` skipped.
"""

from repro.configs.base import ArchConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="granite-3-8b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=12800,
        vocab_size=49155,
        act="silu",
        glu=True,
        norm="rmsnorm",
        tie_embeddings=True,
        source="hf:ibm-granite/granite-3.0-2b-base; hf",
    )
)
