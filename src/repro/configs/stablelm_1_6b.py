"""stablelm-1.6b — dense [hf:stabilityai/stablelm-2-1_6b; unverified].

24L d_model=2048 32H (kv=32) d_ff=5632 vocab=100352.  LayerNorm + partial
rotary (25% of head_dim) per the StableLM-2 config.  Full attention →
``long_500k`` skipped.
"""

from repro.configs.base import ArchConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="stablelm-1.6b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=5632,
        vocab_size=100352,
        act="silu",
        glu=True,
        norm="layernorm",
        partial_rotary=0.25,
        tie_embeddings=False,
        source="hf:stabilityai/stablelm-2-1_6b; unverified",
    )
)
