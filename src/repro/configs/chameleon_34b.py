"""chameleon-34b — early-fusion VLM backbone [arXiv:2405.09818; unverified].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.  Early fusion: VQ
image tokens live inside the 65536 vocab, so the backbone is a decoder-only
LM; the VQ-GAN frontend is a stub (``frontend="vq_stub"`` — input_specs
provides token ids).  QK-norm per the Chameleon paper's training-stability
fix.  Full attention → ``long_500k`` is skipped (DESIGN.md §4).
"""

from repro.configs.base import ArchConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="chameleon-34b",
        family="vlm",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab_size=65536,
        act="silu",
        glu=True,
        norm="rmsnorm",
        qk_norm=True,
        rope_theta=10000.0,
        tie_embeddings=False,
        frontend="vq_stub",
        source="arXiv:2405.09818; unverified",
    )
)
