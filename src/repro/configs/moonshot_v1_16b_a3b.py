"""moonshot-v1-16b-a3b (Moonlight) — MoE [hf:moonshotai/Moonlight-16B-A3B; hf].

48L d_model=2048 16H (GQA kv=16) d_ff=1408(per expert) vocab=163840,
MoE 64 experts top-6.  Assignment-literal: 64e/top-6, no shared expert.
Full attention → ``long_500k`` skipped.
"""

from repro.configs.base import ArchConfig, MoECfg, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=163840,
        act="silu",
        glu=True,
        norm="rmsnorm",
        tie_embeddings=False,
        moe=MoECfg(n_experts=64, top_k=6, d_ff_expert=1408, n_shared_experts=0),
        source="hf:moonshotai/Moonlight-16B-A3B; hf",
    )
)
