"""qwen1.5-0.5b — dense with QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].

24L d_model=1024 16H (kv=16) d_ff=2816 vocab=151936.  Full attention →
``long_500k`` skipped.
"""

from repro.configs.base import ArchConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="qwen1.5-0.5b",
        family="dense",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=2816,
        vocab_size=151936,
        act="silu",
        glu=True,
        norm="rmsnorm",
        qkv_bias=True,
        tie_embeddings=True,
        source="hf:Qwen/Qwen1.5-0.5B; hf",
    )
)
