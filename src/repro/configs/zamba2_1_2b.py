"""zamba2-1.2b — hybrid Mamba2 + shared attention [arXiv:2411.15242; hf].

38L d_model=2048 (Mamba2 blocks, ssm_state=64) with a **shared** attention
block (32H kv=32) applied after every 6th Mamba block — weights shared
across applications, distinct KV caches (arXiv:2411.15242).  Sub-quadratic
backbone → runs ``long_500k``.
"""

from repro.configs.base import ArchConfig, SSMCfg, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32000,
        act="gelu",
        glu=True,
        norm="rmsnorm",
        block_pattern="zamba2",
        ssm=SSMCfg(d_state=64, expand=2, head_dim=64, conv_kernel=4, chunk=256),
        attn_every=6,
        tie_embeddings=True,
        source="arXiv:2411.15242; hf",
    )
)
