"""gemma-2b — dense, GeGLU, MQA [arXiv:2403.08295; hf].

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000, head_dim=256,
GeGLU activation.  Full attention → ``long_500k`` skipped.
"""

from repro.configs.base import ArchConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="gemma-2b",
        family="dense",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=256000,
        act="gelu",
        glu=True,
        norm="rmsnorm",
        tie_embeddings=True,
        source="arXiv:2403.08295; hf",
    )
)
