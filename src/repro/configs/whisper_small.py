"""whisper-small — enc-dec audio [arXiv:2212.04356; unverified].

12L (enc) + 12L (dec), d_model=768 12H d_ff=3072 vocab=51865.  The conv
frontend is a STUB: ``input_specs()`` provides precomputed frame embeddings
(enc_seq=1500 × 768).  Learned positions, LayerNorm, GELU, no GLU —
faithful to Whisper.  Decoder positions are parameterized so the assigned
32k decode shapes lower (noted as a shape exercise in DESIGN.md §4).
Full attention → ``long_500k`` skipped.
"""

from repro.configs.base import ArchConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="whisper-small",
        family="audio",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        act="gelu",
        glu=False,
        norm="layernorm",
        qkv_bias=True,
        mlp_bias=True,
        pos_emb="learned",
        tie_embeddings=True,
        is_encdec=True,
        encoder_layers=12,
        enc_seq=1500,
        frontend="audio_stub",
        source="arXiv:2212.04356; unverified",
    )
)
